"""Async task-scheduler tests: the task table and its dependency edges
(program order, read/write hazards, barriers, data deps), the
submit/poll/wait wire surface, deferred-handle chaining, the wire path of
register_library, and a multi-threaded multi-session stress test proving
concurrency is real while isolation and ordering hold."""
import threading
import time

import msgpack
import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine
from repro.core import protocol
from repro.core.context import AlchemistError
from repro.core.engine import ENGINE_LIBRARY, make_engine_mesh
from repro.core.handles import MatrixHandle
from repro.core.libraries import elemental
from repro.core.scheduler import (
    DONE, FAILED, QUEUED, RUNNING, TaskFailure, TaskScheduler)

RNG = np.random.RandomState(0)


@pytest.fixture()
def engine():
    return AlchemistEngine(make_engine_mesh(1), scheduler_workers=4)


# =====================================================================
# scheduler unit level (engine-agnostic task table)
# =====================================================================
def test_single_task_lifecycle_and_timing():
    sched = TaskScheduler(num_workers=2)
    task = sched.submit(lambda t: 42, session=1, label="answer")
    done = sched.wait(task.id, timeout=10)
    assert done.state == DONE and done.result == 42
    assert done.wait_s >= 0 and done.exec_s >= 0
    assert sched.counts()[DONE] == 1


def test_failed_task_records_error_and_payload():
    sched = TaskScheduler(num_workers=1)
    t1 = sched.submit(lambda t: 1 / 0, session=1)
    t2 = sched.submit(
        lambda t: (_ for _ in ()).throw(TaskFailure(b"payload", "nope")),
        session=1)
    assert sched.wait(t1.id, timeout=10).state == FAILED
    assert "ZeroDivisionError" in sched.task(t1.id).error
    done2 = sched.wait(t2.id, timeout=10)
    assert done2.state == FAILED and done2.result == b"payload"


def test_sessions_overlap_but_program_order_is_serial():
    """Two sessions' tasks run concurrently; one session's never do."""
    sched = TaskScheduler(num_workers=4)
    order = []
    lock = threading.Lock()

    def body(tag, sleep):
        def fn(task):
            time.sleep(sleep)
            with lock:
                order.append(tag)
        return fn

    # session 1: first task sleeps longer than the second — with any
    # intra-session overlap the order would invert
    a1 = sched.submit(body("a1", 0.25), session=1)
    a2 = sched.submit(body("a2", 0.0), session=1)
    b1 = sched.submit(body("b1", 0.25), session=2)
    b2 = sched.submit(body("b2", 0.0), session=2)
    for t in (a1, a2, b1, b2):
        sched.wait(t.id, timeout=30)
    assert order.index("a1") < order.index("a2")
    assert order.index("b1") < order.index("b2")
    assert sched.max_running_observed >= 2     # cross-session overlap


def test_concurrent_readers_overlap_writer_excludes():
    """Hazards on one handle: readers of H run together; a writer of H
    waits for all prior readers and blocks later readers."""
    sched = TaskScheduler(num_workers=4)
    H = 77
    events = []
    lock = threading.Lock()

    def reader(tag):
        def fn(task):
            with lock:
                events.append((tag, "start"))
            time.sleep(0.2)
            with lock:
                events.append((tag, "end"))
        return fn

    # distinct sessions so program order contributes no edges
    r1 = sched.submit(reader("r1"), session=1, reads=[H])
    r2 = sched.submit(reader("r2"), session=2, reads=[H])
    w = sched.submit(reader("w"), session=3, writes=[H])
    r3 = sched.submit(reader("r3"), session=4, reads=[H])
    for t in (r1, r2, w, r3):
        sched.wait(t.id, timeout=30)

    def idx(tag, kind):
        return events.index((tag, kind))

    # both readers started before either ended => they overlapped
    assert max(idx("r1", "start"), idx("r2", "start")) < \
        min(idx("r1", "end"), idx("r2", "end"))
    # writer strictly after both readers finished
    assert idx("w", "start") > max(idx("r1", "end"), idx("r2", "end"))
    # reader after the write strictly after the writer finished
    assert idx("r3", "start") > idx("w", "end")


def test_write_write_hazard_orders_writers():
    sched = TaskScheduler(num_workers=4)
    H = 5
    seen = []
    w1 = sched.submit(lambda t: (time.sleep(0.2), seen.append("w1")),
                      session=1, writes=[H])
    w2 = sched.submit(lambda t: seen.append("w2"), session=2, writes=[H])
    sched.wait(w1.id, timeout=30)
    sched.wait(w2.id, timeout=30)
    assert seen == ["w1", "w2"]


def test_barrier_waits_for_all_and_blocks_later():
    sched = TaskScheduler(num_workers=4)
    events = []
    lock = threading.Lock()

    def mark(tag, sleep=0.0):
        def fn(task):
            time.sleep(sleep)
            with lock:
                events.append(tag)
        return fn

    t1 = sched.submit(mark("t1", 0.2), session=1)
    t2 = sched.submit(mark("t2", 0.2), session=2)
    bar = sched.submit(mark("bar"), session=3, barrier=True)
    t3 = sched.submit(mark("t3"), session=4)
    for t in (t1, t2, bar, t3):
        sched.wait(t.id, timeout=30)
    assert events.index("bar") > max(events.index("t1"), events.index("t2"))
    assert events.index("t3") > events.index("bar")


def test_failure_propagates_only_through_data_deps():
    sched = TaskScheduler(num_workers=2)
    bad = sched.submit(lambda t: 1 / 0, session=1)
    # same-session successor (program-order edge only): must still run
    ok = sched.submit(lambda t: "fine", session=1)
    # data-dependent consumer (any session): must fail without running
    ran = []
    consumer = sched.submit(lambda t: ran.append(1), session=2,
                            data_deps=[bad.id])
    assert sched.wait(ok.id, timeout=30).result == "fine"
    got = sched.wait(consumer.id, timeout=30)
    assert got.state == FAILED and "upstream task" in got.error
    assert not ran
    # a data dep that already failed before submission also propagates
    late = sched.submit(lambda t: ran.append(2), session=3,
                        data_deps=[bad.id])
    assert sched.wait(late.id, timeout=30).state == FAILED
    assert not ran


def test_scheduler_wait_timeout_and_unknown_task():
    sched = TaskScheduler(num_workers=1)
    t = sched.submit(lambda task: time.sleep(0.5), session=1)
    with pytest.raises(TimeoutError):
        sched.wait(t.id, timeout=0.01)
    with pytest.raises(KeyError):
        sched.wait(98765)
    sched.wait(t.id, timeout=30)


# =====================================================================
# protocol: submit/poll/wait wire surface
# =====================================================================
def test_task_op_roundtrip_and_bad_action():
    op = protocol.TaskOp(action=protocol.WAIT, task=9, session=3)
    assert protocol.decode_task_op(protocol.encode_task_op(op)) == op
    with pytest.raises(ValueError):
        protocol.encode_task_op(protocol.TaskOp(action="cancel", task=1))


def test_task_op_wire_requires_session_field():
    with pytest.raises(KeyError):
        protocol.decode_task_op(msgpack.packb({"action": "poll", "task": 1}))


def test_deferred_handle_roundtrips_inside_command():
    d = protocol.DeferredHandle(task=4, key="Q")
    cmd = protocol.Command("lib", "fn", {"A": d, "nest": [d, 1]}, session=2)
    back = protocol.decode_command(protocol.encode_command(cmd))
    assert back.args["A"] == d and back.args["nest"][0] == d


def test_result_roundtrips_task_and_timing_fields():
    res = protocol.Result(values={}, error="", session=2, task=11,
                          state="DONE", wait_s=0.5, exec_s=1.5)
    back = protocol.decode_result(protocol.encode_result(res))
    assert back == res


def test_result_decode_tolerates_pre_scheduler_wire_bytes():
    old = msgpack.packb({"values": {}, "elapsed": 0.1, "error": "",
                        "session": 4})
    res = protocol.decode_result(old)
    assert res.task == 0 and res.state == "" and res.wait_s == 0.0


# =====================================================================
# engine + context: async calls, futures, chaining
# =====================================================================
def test_call_async_returns_future_then_result(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    fut = ac.call_async("elemental", "random_matrix", rows=16, cols=4)
    out = fut.result()
    assert out["A"].shape == (16, 4)
    assert fut.done() and fut.state() == "DONE"
    assert out["_exec_s"] > 0 and out["_wait_s"] >= 0
    # a completed future resolves its outputs to real handles
    assert isinstance(fut["A"], MatrixHandle)


def test_deferred_chain_pipelines_engine_side(engine):
    """Submit a 3-deep chain in one burst; handles resolve engine-side."""
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.2: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    ac.call_async("slow", "nap")             # pins the session's queue
    f1 = ac.call_async("elemental", "random_matrix", rows=24, cols=6,
                       seed=5)
    f2 = ac.call_async("elemental", "gram", A=f1["A"])
    f3 = ac.call_async("elemental", "multiply", A=f1["A"], B=f2["G"])
    # while the producer is still queued, outputs are placeholders
    assert isinstance(f1["A"], protocol.DeferredHandle)
    got = ac.wrap(f3.result()["C"]).to_numpy()
    a = ac.wrap(f1["A"]).to_numpy()          # real handle once finished
    np.testing.assert_allclose(got, a @ (a.T @ a), rtol=1e-4, atol=1e-5)


def test_poll_observes_nonterminal_then_terminal_state(engine):
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.3: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    ac = AlchemistContext(engine=engine)
    fut = ac.call_async("slow", "nap")
    assert fut.state() in (QUEUED, RUNNING, DONE)
    assert fut.result()["ok"] == 1
    assert fut.state() == DONE


def test_failed_routine_surfaces_via_future_and_poisons_only_dependents(
        engine):
    def boom(eng, s=0.3):
        time.sleep(s)
        raise RuntimeError("boom")

    class _Bad:
        ROUTINES = {"boom": boom}

    engine.load_library("badlib", _Bad)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    bad = ac.call_async("badlib", "boom")
    # submitted while the producer is still in flight -> deferred edge
    dependent = ac.call_async("elemental", "multiply", A=bad["G"],
                              B=bad["G"])
    independent = ac.call_async("elemental", "random_matrix", rows=4,
                                cols=4)
    with pytest.raises(AlchemistError, match="RuntimeError: boom"):
        bad.result()
    with pytest.raises(AlchemistError, match="upstream task"):
        dependent.result()
    assert independent.result()["A"].shape == (4, 4)   # not poisoned
    assert bad.state() == FAILED and independent.state() == DONE
    # chaining on a producer already known to have failed errors with a
    # clear message, client-side, instead of minting a doomed task
    with pytest.raises(AlchemistError, match="failed"):
        bad["G"]


def test_future_getitem_on_missing_output_key(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    fut = ac.call_async("elemental", "qr", A=ac.send_matrix(RNG.randn(8, 4)))
    fut.result()
    with pytest.raises(KeyError, match="no handle named"):
        fut["Z"]


def test_deferred_missing_key_fails_consumer_not_workers(engine):
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.2: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    ac.call_async("slow", "nap")             # keeps f1 QUEUED (deferred)
    f1 = ac.call_async("elemental", "random_matrix", rows=8, cols=4)
    f2 = ac.call_async("elemental", "gram", A=f1["NOPE"])
    with pytest.raises(AlchemistError, match="no handle named"):
        f2.result()
    # pool still alive
    assert ac.call("elemental", "random_matrix", rows=4,
                   cols=4)["A"].shape == (4, 4)


def test_blocking_calls_do_not_accumulate_task_rows(engine):
    """Delivery releases the table row: a long-lived session of blocking
    calls leaves the task table empty (the TaskLog keeps the accounting)."""
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    for i in range(5):
        ac.call("elemental", "random_matrix", rows=4, cols=4, seed=i)
    assert sum(engine.scheduler.counts().values()) == 0
    assert engine.task_log.session_summary(ac.session)["tasks"] == 5


def test_release_keeps_producer_row_for_terminal_data_dep():
    """Regression: a consumer whose data dep was already DONE at submit
    time still pins the producer's row until the consumer is terminal —
    otherwise a concurrent result delivery (wait -> release) between
    submit and execution drops the row and deferred resolution fails
    with "unknown task". Found by the traced-engine stress run
    (tests/test_analysis.py)."""
    sched = TaskScheduler(num_workers=1)
    gate = threading.Event()
    producer = sched.submit(lambda t: {"A": 7}, session=1)
    assert sched.wait(producer.id, timeout=10).state == DONE

    # occupy the single worker so the consumer stays QUEUED
    blocker = sched.submit(lambda t: gate.wait(10), session=1)
    consumer = sched.submit(
        lambda t: sched.task(producer.id).result["A"],
        session=1, data_deps=(producer.id,))

    # the delivery-time release must refuse while the consumer is live
    assert sched.release(producer.id) is False
    assert sched.task(producer.id).result == {"A": 7}

    gate.set()
    done = sched.wait(consumer.id, timeout=10)
    assert done.state == DONE and done.result == 7
    sched.wait(blocker.id, timeout=10)
    # ... and succeed once nothing depends on the row any more
    assert sched.release(producer.id) is True


def test_cross_session_deferred_is_refused_at_submit(engine):
    """Deferred handles are session-scoped: chaining on another tenant's
    task is rejected before a task (and a dependency edge onto the other
    session's work) is ever minted."""
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.3: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    engine.load_library("elemental", elemental)
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    a.call_async("slow", "nap")              # keeps fa QUEUED (deferred)
    fa = a.call_async("elemental", "random_matrix", rows=8, cols=4)
    with pytest.raises(AlchemistError, match="does not belong to session"):
        b.call_async("elemental", "gram", A=fa["A"])
    fa.result()


def test_disconnect_forgets_the_sessions_task_rows(engine):
    """Stop prunes the departed session's terminal tasks: the table stays
    bounded by connected tenants, and old task IDs stop resolving."""
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    futs = [ac.call_async("elemental", "random_matrix", rows=4, cols=4,
                          seed=i) for i in range(3)]
    futs[-1].result()
    tasks = [f.task for f in futs]
    ac.stop()
    for tid in tasks:
        with pytest.raises(KeyError):
            engine.scheduler.task(tid)
    # hazard maps are pruned too once nothing is in flight
    assert not engine.scheduler._readers and not engine.scheduler._writer


def test_submit_after_shutdown_returns_error_result(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    ac.send_matrix(RNG.randn(4, 4))
    engine.shutdown()
    assert engine.resident_bytes() == 0        # matrices dropped too
    # wire clients get a clean error Result (session gone), never a raw
    # exception; the scheduler itself refuses new work too
    with pytest.raises(AlchemistError, match="not connected"):
        ac.call_async("elemental", "random_matrix", rows=4, cols=4)
    with pytest.raises(RuntimeError, match="shut down"):
        engine.scheduler.submit(lambda t: None, session=0)
    engine.shutdown()                          # idempotent


def test_concurrent_waiters_on_one_task_both_get_results(engine):
    """Two threads waiting the same task race the release-on-delivery:
    the loser must get an encoded error Result (or the same values),
    never a raw exception through the wire endpoint."""
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    fut = ac.call_async("elemental", "random_matrix", rows=8, cols=8)
    outs = []

    def waiter():
        outs.append(protocol.decode_result(engine.task_op(
            protocol.encode_task_op(protocol.TaskOp(
                action=protocol.WAIT, task=fut.task,
                session=ac.session)))))

    ts = [threading.Thread(target=waiter) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(outs) == 4
    delivered = [r for r in outs if not r.error]
    assert delivered and all("A" in r.values for r in delivered)
    for r in outs:
        if r.error:                       # raced the release: clean error
            assert "unknown task" in r.error


def test_passing_future_directly_is_a_type_error(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    fut = ac.call_async("elemental", "random_matrix", rows=4, cols=4)
    with pytest.raises(TypeError, match="named output"):
        ac.call_async("elemental", "gram", A=fut)
    fut.result()


def test_task_ops_are_session_scoped(engine):
    engine.load_library("elemental", elemental)
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    fut = a.call_async("elemental", "random_matrix", rows=4, cols=4)
    res = protocol.decode_result(engine.task_op(protocol.encode_task_op(
        protocol.TaskOp(action=protocol.POLL, task=fut.task,
                        session=b.session))))
    assert "does not belong to session" in res.error
    fut.result()


def test_submit_fast_fails_without_minting_tasks(engine):
    before = engine.scheduler.counts()
    res = protocol.decode_result(engine.submit(b"\x00garbage"))
    assert res.error and res.task == 0
    wire = protocol.encode_command(protocol.Command(
        "elemental", "gram", {}, session=999))
    res = protocol.decode_result(engine.submit(wire))
    assert "UnknownSession" in res.error
    wire = protocol.encode_command(protocol.Command(
        "elemental", "gram", {}, session=0))
    res = protocol.decode_result(engine.submit(wire))
    assert "system session" in res.error
    assert engine.scheduler.counts() == before


def test_stop_drains_in_flight_tasks_before_reclaiming(engine):
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.3: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    ac = AlchemistContext(engine=engine)
    ac.send_matrix(RNG.randn(8, 4))
    fut = ac.call_async("slow", "nap")
    ac.stop()                      # must wait for the nap, then reclaim
    assert engine.resident_bytes() == 0
    # the nap ran to completion (drained, not cancelled)...
    rec = [r for r in engine.task_log.records if r.label == "slow.nap"]
    assert rec and rec[0].state == DONE
    # ...and the departed session's task rows were pruned
    with pytest.raises(KeyError):
        engine.scheduler.task(fut.task)


# ---- engine.overwrite: the write path hazards order against ----
def test_overwrite_in_place_keeps_id_and_refcount(engine):
    h = engine.put(np.zeros((4, 4), np.float32))
    engine.retain(h)
    engine.overwrite(h, np.asarray(np.ones((4, 4), np.float32)))
    assert engine.refcount(h) == 2
    np.testing.assert_array_equal(np.asarray(engine.get(h)),
                                  np.ones((4, 4), np.float32))


def test_overwrite_guards_shape_dtype_and_owner(engine):
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(RNG.randn(4, 4).astype(np.float32))
    with pytest.raises(ValueError, match="must keep shape"):
        engine.overwrite(al.handle, np.zeros((2, 2), np.float32))
    other = AlchemistContext(engine=engine)
    with pytest.raises(KeyError):
        engine.overwrite(al.handle, np.zeros((4, 4), np.float32),
                         session=other.session)


def test_declared_write_routine_is_hazard_tracked(engine):
    """A routine declaring writes=("A",) gets write edges: its effect is
    ordered against the session's surrounding reads."""
    def scale(eng, A, factor=2.0):
        eng.overwrite(A, eng.get(A) * factor)
        return {"A": A}
    scale.writes = ("A",)

    def total(eng, A):
        return {"sum": float(np.asarray(eng.get(A)).sum())}

    class _Lib:
        ROUTINES = {"scale": scale, "total": total}

    engine.load_library("w", _Lib)
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(np.ones((8, 2), np.float32))
    f1 = ac.call_async("w", "total", A=al)
    f2 = ac.call_async("w", "scale", A=al, factor=3.0)
    f3 = ac.call_async("w", "total", A=al)
    assert f1.result()["sum"] == 16.0
    assert f3.result()["sum"] == 48.0
    f2.result()


# =====================================================================
# register_library through the wire
# =====================================================================
def test_register_library_goes_through_the_wire(engine):
    ac = AlchemistContext(engine=engine)
    ac.register_library("elemental", elemental)
    assert "elemental" in engine.libraries()
    assert ac.call("elemental", "random_matrix", rows=4,
                   cols=4)["A"].shape == (4, 4)
    # registration executed as a command in this session
    assert any(r.label == f"{ENGINE_LIBRARY}.load_library"
               for r in engine.task_log.records)


def test_register_library_rejects_non_modules(engine):
    ac = AlchemistContext(engine=engine)

    class _NotAModule:
        ROUTINES = {}

    with pytest.raises(TypeError, match="import path"):
        ac.register_library("x", _NotAModule)


def test_register_library_bad_module_path_errors_cleanly(engine):
    ac = AlchemistContext(engine=engine)
    wire = protocol.encode_command(protocol.Command(
        ENGINE_LIBRARY, "load_library",
        {"name": "x", "module": "repro.no.such.module"},
        session=ac.session))
    res = protocol.decode_result(engine.run(wire))
    assert "ModuleNotFoundError" in res.error
    # the engine survives; later loads work
    ac.register_library("elemental", elemental)


def test_load_library_serializes_with_in_flight_tasks(engine):
    """The load is a barrier: a submission racing a slow task still sees
    the library once its turn comes (submit-time lookup is deferred)."""
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.4: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    nap = a.call_async("slow", "nap")
    b.register_library("elemental", elemental)      # barrier behind nap
    out = b.call("elemental", "random_matrix", rows=4, cols=4)
    assert out["A"].shape == (4, 4)
    nap.result()
    # barrier ordering is visible in the completion log
    labels = [r.label for r in engine.task_log.records]
    assert labels.index("slow.nap") < \
        labels.index(f"{ENGINE_LIBRARY}.load_library")


def test_reserved_library_name_cannot_be_shadowed(engine):
    with pytest.raises(ValueError, match="reserved"):
        engine.load_library(ENGINE_LIBRARY, elemental)


# =====================================================================
# the multi-threaded multi-session stress test
# =====================================================================
def test_stress_many_threads_many_sessions(engine):
    """N client threads × M sessions issuing interleaved async chains:
    namespace isolation, per-session ordering, hazard-correct chaining
    through deferred handles, failure isolation, real overlap."""
    engine.load_library("elemental", elemental)

    class _Aux:
        ROUTINES = {
            "nap": lambda eng, s=0.05: time.sleep(s) or {"ok": 1},
        }

    engine.load_library("aux", _Aux)

    num_threads = 4
    chains_per_thread = 3
    ctxs = [AlchemistContext(engine=engine, client_name=f"app-{i}")
            for i in range(num_threads)]
    errors: list[Exception] = []
    results: dict[int, list] = {i: [] for i in range(num_threads)}

    def work(ti: int, ac: AlchemistContext):
        try:
            for c in range(chains_per_thread):
                seed = 101 * ti + c
                f1 = ac.call_async("elemental", "random_matrix", rows=24,
                                   cols=6, seed=seed)
                ac.call_async("aux", "nap")        # keeps workers busy
                f2 = ac.call_async("elemental", "gram", A=f1["A"])
                f3 = ac.call_async("elemental", "multiply", A=f1["A"],
                                   B=f2["G"])
                if ti == 0 and c == 1:
                    # one session's failing routine...
                    ghost = MatrixHandle.fresh((3, 3), "float32")
                    bad = ac.call_async("elemental", "gram", A=ghost)
                    with pytest.raises(AlchemistError):
                        bad.result()
                out = f3.result()
                a = np.asarray(engine.get(f1["A"]))
                got = np.asarray(engine.get(out["C"]))
                results[ti].append((got, a @ (a.T @ a)))
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i, ac))
               for i, ac in enumerate(ctxs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not errors
    # ...never poisons another session's futures: every chain of every
    # session (including the failing one's other chains) is correct
    for ti, pairs in results.items():
        assert len(pairs) == chains_per_thread
        for got, want in pairs:
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
    # concurrency was real: >1 task RUNNING at some point
    assert engine.scheduler.max_running_observed > 1
    # namespace isolation held: every handle minted by session i is owned
    # by session i only
    owned = [engine.session(ac.session).owned for ac in ctxs]
    for i in range(len(owned)):
        for j in range(i + 1, len(owned)):
            assert not (owned[i] & owned[j])
    # per-session program order: the task log records completions; within
    # a session, submission ids must complete respecting program order —
    # verified by per-session task wait/exec accounting being complete
    for ac in ctxs:
        summary = engine.task_log.session_summary(ac.session)
        assert summary["tasks"] >= 4 * chains_per_thread
        assert summary["p99_latency_s"] >= summary["p50_latency_s"] >= 0
    for ac in ctxs:
        ac.stop()
    assert engine.resident_bytes() == 0
