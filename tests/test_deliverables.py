"""Deliverable-integrity checks: the dry-run artifact sets are complete and
well-formed (these are what EXPERIMENTS.md §Dry-run/§Roofline read)."""
import glob
import json
import os

import pytest

from repro.common.config import SHAPES
from repro.configs import ASSIGNED, get_config, supports_shape

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "results", "dryrun")

_HAVE_ARTIFACTS = bool(glob.glob(os.path.join(BASELINE_DIR, "*.json")))
needs_artifacts = pytest.mark.skipif(
    not _HAVE_ARTIFACTS, reason="run repro.launch.dryrun --all first")


def _expected_combos():
    combos = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if supports_shape(cfg, shape):
                combos.append((arch, shape_name))
    combos.append(("qwen3-4b-sw", "long_500k"))
    return combos


@needs_artifacts
@pytest.mark.parametrize("mesh", ["pod16x16", "pod2x16x16"])
def test_every_supported_combo_has_a_baseline_artifact(mesh):
    missing = []
    for arch, shape in _expected_combos():
        path = os.path.join(BASELINE_DIR, f"{arch}_{shape}_{mesh}.json")
        if not os.path.exists(path):
            missing.append((arch, shape))
    assert not missing, missing


@needs_artifacts
def test_artifacts_are_well_formed():
    for path in glob.glob(os.path.join(BASELINE_DIR, "*.json")):
        data = json.load(open(path))
        r = data["roofline"]
        assert r["dominant"] in ("compute", "memory", "collective"), path
        assert r["model_flops"] > 0, path
        assert data["chips"] in (256, 512), path
        assert data["compile_s"] > 0, path
        # decode shapes must never report zero-size caches for cache archs
        if data["shape"] in ("decode_32k", "long_500k"):
            assert data["memory_analysis"]["argument_size_bytes"] > 0, path


def test_expected_combo_count_matches_design():
    """10 archs x 4 shapes minus documented long_500k skips + the sw
    variant = 33 combos per mesh (DESIGN.md §5)."""
    assert len(_expected_combos()) == 33
