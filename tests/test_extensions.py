"""Tests for the extension features: fused normal-matvec kernel, NMF
routine, offloaded linear-head fitting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AlchemistContext
from repro.core.libraries import elemental, skylark
from repro.kernels.normal_matvec.normal_matvec import normal_matvec_pallas
from repro.kernels.normal_matvec.ops import normal_matvec
from repro.kernels.normal_matvec.ref import normal_matvec_ref


@pytest.mark.parametrize("n,d,c", [(256, 64, 4), (300, 128, 1),
                                   (512, 440, 16), (1000, 37, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_normal_matvec_matches_ref(n, d, c, dtype):
    key = jax.random.PRNGKey(n + d + c)
    x = jax.random.normal(key, (n, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, c), jnp.float32)
    got = normal_matvec(x, w, use_pallas=True, bm=128)
    want = normal_matvec_ref(x, w)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.abs(want).max()))


def test_normal_matvec_padding_is_exact():
    """Zero-row padding must not perturb X^T X w."""
    x = jax.random.normal(jax.random.PRNGKey(0), (130, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 2), jnp.float32)
    got = normal_matvec(x, w, use_pallas=True, bm=128)   # pads 130 -> 256
    want = normal_matvec_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-4)


def test_cg_with_fused_kernel_matches_direct():
    ac = AlchemistContext(num_workers=1)
    ac.register_library("skylark", skylark)
    rng = np.random.RandomState(0)
    x = rng.randn(256, 24).astype(np.float32)
    y = rng.randn(256, 2).astype(np.float32)
    res = ac.call("skylark", "cg_solve", X=ac.send_matrix(x),
                  Y=ac.send_matrix(y), lam=1e-3, max_iters=300, tol=1e-10,
                  use_pallas=True)
    w = ac.wrap(res["W"]).to_numpy()
    want = np.linalg.solve(x.T @ x + 256 * 1e-3 * np.eye(24), x.T @ y)
    np.testing.assert_allclose(w, want, atol=1e-4)


def test_nmf_reduces_residual_and_stays_nonnegative():
    ac = AlchemistContext(num_workers=1)
    ac.register_library("skylark", skylark)
    rng = np.random.RandomState(0)
    truth = rng.rand(80, 4) @ rng.rand(4, 30)
    res = ac.call("skylark", "nmf", A=ac.send_matrix(truth), k=4,
                  max_iters=200)
    w = ac.wrap(res["W"]).to_numpy()
    h = ac.wrap(res["H"]).to_numpy()
    assert (w >= 0).all() and (h >= 0).all()
    assert res["relative_residual"] < 0.05
    np.testing.assert_allclose(w @ h, truth, atol=0.3)


def test_offloaded_linear_probe_beats_chance():
    from repro.common.config import ShapeConfig
    from repro.configs import get_reduced
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import build_model
    from repro.nn.core import init_params
    from repro.train.offload import (
        extract_features,
        fit_linear_head_cg,
        head_accuracy,
    )

    cfg = get_reduced("stablelm-1.6b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    shape = ShapeConfig("probe", seq_len=16, global_batch=16, mode="train")
    data = SyntheticLM(cfg, shape, seed=0, bigram_q=1.0)
    feats, labels = extract_features(
        model, params, (data.batch(i) for i in range(6)), max_batches=6)
    # restrict to a small label space for a learnable probe
    labels = labels % 8

    ac = AlchemistContext(num_workers=1)
    ac.register_library("skylark", skylark)
    w, res = fit_linear_head_cg(ac, feats, labels, num_classes=8, lam=1e-4)
    acc = head_accuracy(w, feats, labels)
    assert acc > 1.5 / 8, acc          # comfortably above the 1/8 chance
