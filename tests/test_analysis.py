"""The invariant checker's own conformance suite.

Two halves, mirroring ``repro.analysis``:

* every static rule family (CAT/WIRE/BRG/TRC/PKL/LCK) is proven with a
  **fixture that violates exactly it** — fake catalogs/backends for the
  registry rules, crafted frame tables for the wire rules, and
  ``tests/fixtures/analysis_violations.py`` (parsed as source, never
  imported) for the AST rules — and proven **quiet on the real tree**,
  so the CI gate is neither toothless nor noisy;
* the dynamic lock-order detector is unit-tested on private
  :class:`LockTrace` instances (cycle, rank inversion, wait-under-lock)
  and then run for real: a multi-thread multi-session stress over a
  fully traced engine + TCP server, asserting the recorded acquisition
  graph is acyclic and rank-consistent.
"""
import json
import os
import threading
import time
import types

import numpy as np
import pytest

from repro.analysis import locktrace, run_all_rules
from repro.analysis import findings as F
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.rules_catalog import check_catalog_parity
from repro.analysis.rules_config import check_config_surface
from repro.analysis.rules_source import (
    check_lock_discipline, check_lock_ranks, check_no_pickle,
    check_trace_purity)
from repro.analysis.rules_stm import check_statemachines
from repro.analysis.statemachine import Edge, Machine, Obligation
from repro.analysis.rules_wire import (
    check_bridge_parity, check_wire_exhaustiveness)
from repro.core.backends.base import ExecutionBackend
from repro.core.wire import FrameSpec

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "analysis_violations.py")


def _by_rule(findings, rule):
    return [f for f in findings if f.rule == rule]


# =====================================================================
# CAT — catalog parity, against a deliberately drifted fake registry
# =====================================================================
def _spec_fn_mul(engine, A, B):
    raise NotImplementedError


def _spec_fn_solo(engine, A):
    raise NotImplementedError


class _FakeA(ExecutionBackend):
    name = "fake-a"

    def to_native(self, array):
        return array

    def is_array(self, value):
        return False


class _FakeB(ExecutionBackend):
    name = "fake-b"

    def to_native(self, array):
        return array

    def is_array(self, value):
        return False


# CAT004: bucketable without a shape rule (and fusible=True for CAT003)
@_FakeA.register("fakelib", "mul", fusible=True, bucketable=True)
def _a_mul(A=None, B=None):
    return {"C": A}


# CAT005: spec declares output "X", the impl only ever returns "Y"
@_FakeA.register("fakelib", "solo")
def _a_solo(A=None):
    return {"Y": A}


# CAT002: registered under the cataloged library, never declared
@_FakeA.register("fakelib", "orphan")
def _a_orphan(A=None):
    return {"Z": A}


# CAT003: fusible drifts from _FakeA's registration of the same routine
@_FakeB.register("fakelib", "mul", fusible=False)
def _b_mul(A=None, B=None):
    return {"C": A}
# CAT001: _FakeB never registers fakelib.solo


@pytest.fixture()
def fake_catalog():
    spec = types.SimpleNamespace
    module = spec(
        __file__=__file__,
        ROUTINES={
            "mul": spec(fn=_spec_fn_mul, outputs=("C",)),
            "solo": spec(fn=_spec_fn_solo, outputs=("X",)),
        })
    return {"fakelib": module}, [_FakeA(), _FakeB()]


def test_cat_rules_fire_on_drifted_registry(fake_catalog):
    libraries, backends = fake_catalog
    found = check_catalog_parity(libraries=libraries, backends=backends)

    missing = _by_rule(found, "CAT001")
    assert [f.symbol for f in missing] == ["fakelib.solo@fake-b"]

    orphans = _by_rule(found, "CAT002")
    assert [f.symbol for f in orphans] == ["fakelib.orphan@fake-a"]

    drift = _by_rule(found, "CAT003")
    assert [f.symbol for f in drift] == ["fakelib.mul"]
    assert "fusible" in drift[0].message
    assert "bucketable" in drift[0].message     # True on A, False on B

    bucket = _by_rule(found, "CAT004")
    assert [f.symbol for f in bucket] == ["fakelib.mul@fake-a"]

    arity = _by_rule(found, "CAT005")
    assert [f.symbol for f in arity] == ["fakelib.solo@fake-a"]
    assert "X" in arity[0].message


def test_cat_quiet_when_registry_agrees():
    spec = types.SimpleNamespace
    module = spec(__file__=__file__,
                  ROUTINES={"mul": spec(fn=_spec_fn_mul,
                                        outputs=("C",))})
    # only _FakeB (no orphan, fusible=False everywhere): nothing drifts
    assert check_catalog_parity(libraries={"fakelib": module},
                                backends=[_FakeB()]) == []


# =====================================================================
# WIRE/BRG — frame-table exhaustiveness on crafted registries
# =====================================================================
def test_wire001_registry_integrity():
    bad = (
        FrameSpec("A", 0x01, "request", "handshake", ("RESULT",)),
        FrameSpec("B", 0x01, "request", "submit", ("RESULT",)),
        FrameSpec("C", 0x02, "request", "", ()),
        FrameSpec("RESULT", 0x10, "reply"),
        FrameSpec("D", 0x03, "request", "describe", ("GHOST",)),
        FrameSpec("E", 0x04, "reply", endpoint="submit"),
    )
    syms = {f.symbol for f in
            _by_rule(check_wire_exhaustiveness(frame_specs=bad),
                     "WIRE001")}
    assert "0x01" in syms          # duplicate code
    assert "C" in syms             # request without an endpoint
    assert "D->GHOST" in syms      # reply naming an unregistered frame
    assert "E" in syms             # non-request declaring an endpoint


def test_wire002_unhandled_request_frame():
    specs = (
        FrameSpec("BOGUS", 0x44, "request", "bogus_endpoint",
                  ("RESULT",)),
        FrameSpec("RESULT", 0x10, "reply"),
    )
    found = _by_rule(check_wire_exhaustiveness(frame_specs=specs),
                     "WIRE002")
    assert [f.symbol for f in found] == ["BOGUS"]
    assert "bogus_endpoint" in found[0].message


def test_wire003_frame_the_client_never_sends():
    # endpoint resolves on the engine (WIRE002 quiet) but SocketBridge's
    # source never references FRAME_GHOSTCALL
    specs = (
        FrameSpec("GHOSTCALL", 0x45, "request", "describe",
                  ("RESULT",)),
        FrameSpec("RESULT", 0x10, "reply"),
    )
    found = check_wire_exhaustiveness(frame_specs=specs)
    assert [f.symbol for f in _by_rule(found, "WIRE002")] == []
    assert [f.symbol for f in _by_rule(found, "WIRE003")] == \
        ["GHOSTCALL"]


def test_brg001_bridge_missing_consumer_surface():
    class _NotABridge:            # no submit/handshake/fetch/...
        def close(self):
            pass

    found = _by_rule(check_bridge_parity(bridge_cls=_NotABridge),
                     "BRG001")
    syms = {f.symbol for f in found}
    assert "submit" in syms       # context.py calls .submit() on bridges
    assert all("_NotABridge does not provide it" in f.message
               for f in found)


def test_wire_rules_quiet_on_real_registry():
    assert check_wire_exhaustiveness() == []
    assert check_bridge_parity() == []


# =====================================================================
# TRC/PKL/LCK — AST rules against the violating fixture module
# =====================================================================
def test_trc001_fires_on_every_impurity_in_fixture():
    found = check_trace_purity(paths=[FIXTURE], include_fusible=False)
    assert all(f.rule == "TRC001" for f in found)
    by_fn = {}
    for f in found:
        by_fn.setdefault(f.symbol.split(":")[1], []).append(f.message)
    # the jitted function: I/O, host materialization, sync, locking
    impure = "\n".join(by_fn["impure_traced"])
    assert "print()" in impure
    assert "np.asarray()" in impure
    assert ".block_until_ready()" in impure
    assert "with _lock:" in impure
    # the pallas kernel (found via pallas_call first-arg, no decorator)
    assert len(by_fn["_bad_kernel"]) == 1
    assert by_fn["_bad_kernel"][0].startswith("print()")
    assert len(found) == 5


def test_pkl001_fires_on_pickle_in_fixture():
    found = check_no_pickle(paths=[FIXTURE])
    assert [f.rule for f in found] == ["PKL001", "PKL001"]
    syms = {f.symbol for f in found}
    assert "analysis_violations.py:import-pickle" in syms
    assert "analysis_violations.py:pickle.loads" in syms


def test_lck001_fires_on_raw_lock_in_fixture():
    found = check_lock_discipline(paths=[FIXTURE])
    assert [f.symbol for f in found] == \
        ["analysis_violations.py:threading.Lock"]


def test_source_rules_quiet_on_real_tree():
    assert check_trace_purity() == []
    assert check_no_pickle() == []
    assert check_lock_discipline() == []


# =====================================================================
# STM — state-machine conformance, against a crafted spec + fixture
# =====================================================================
_FX_MACHINE = Machine(
    name="fx", subject="fixture row",
    modules=("stm_violations.py",),
    guarded=("_rows",),
    states=("OPEN", "CLOSED"), initial="OPEN", terminal=("CLOSED",),
    lock="fx.lock", lockattr="_lk",
    mint_sites=("open_row",),
    edges=(Edge("OPEN", "CLOSED", "close_row"),),
    extra_sites=("ghost_site",),            # STM002: does not exist
    obligations=(Obligation("close_row", ("unhook",),
                            "closed rows must unhook their watchers"),),
)


def test_stm_rules_fire_on_violating_fixture():
    found = check_statemachines(machines=(_FX_MACHINE,),
                                root=os.path.dirname(FIXTURE))
    by_rule = {}
    for f in found:
        by_rule.setdefault(f.rule, []).append(f.symbol)
    assert by_rule["STM001"] == ["fx.rogue_drop._rows"]
    assert by_rule["STM002"] == ["fx.ghost_site"]
    assert by_rule["STM003"] == ["fx.close_row._rows"]
    assert by_rule["STM004"] == ["fx.close_row.unhook"]
    assert len(found) == 4                  # open_row is clean


def test_stm_quiet_on_real_tree():
    assert check_statemachines() == []


# =====================================================================
# CFG001 — configure-surface parity, against crafted drifted surfaces
# =====================================================================
def test_cfg001_fires_on_every_drifted_surface(tmp_path):
    (tmp_path / "engine.py").write_text(
        "class E:\n"
        "    def configure(self, opts):\n"
        "        allowed = {'warmup'}      # literal set, not the registry\n"
        "        return allowed\n")
    (tmp_path / "protocol.py").write_text(
        "class Configure:\n"
        "    '''Session configure frame. Mentions no options at all.'''\n")
    (tmp_path / "context.py").write_text(
        "class C:\n"
        "    def configure(self, warmup=None, bogus=None):\n"
        "        pass\n")
    (tmp_path / "server.py").write_text(
        "def build_parser(ap):\n"
        "    return ap                     # defines no flags\n")
    opts = [types.SimpleNamespace(name="warmup", cli="--warmup")]
    found = check_config_surface(
        options=opts,
        engine_path=str(tmp_path / "engine.py"),
        protocol_path=str(tmp_path / "protocol.py"),
        context_path=str(tmp_path / "context.py"),
        server_path=str(tmp_path / "server.py"))
    assert all(f.rule == "CFG001" for f in found)
    syms = {f.symbol for f in found}
    assert syms == {
        "engine.configure:SUPPORTED",       # no registry reference
        "engine.configure:QOS_OPTIONS",     # no QoS gating reference
        "protocol.Configure:warmup",        # docstring omits the option
        "context.configure:bogus",          # unregistered client kwarg
        "server.cli:warmup",                # declared flag undefined
    }


def test_cfg001_quiet_on_real_tree():
    assert check_config_surface() == []


# =====================================================================
# LCK002 — rank uniqueness + docs↔code rank-table parity
# =====================================================================
def _rank_doc(tmp_path, rows):
    doc = tmp_path / "architecture.md"
    table = "\n".join(f"| {r} | `{n}` | prose |" for n, r in rows)
    doc.write_text("intro\n\n<!-- LOCK_RANK_TABLE_BEGIN -->\n"
                   "| rank | lock | held by |\n|---|---|---|\n"
                   + table + "\n<!-- LOCK_RANK_TABLE_END -->\n")
    return str(doc)


def test_lck002_duplicate_ranks(tmp_path):
    doc = _rank_doc(tmp_path, [("a.x", 10), ("b.y", 10)])
    found = check_lock_ranks(ranks={"a.x": 10, "b.y": 10}, doc_path=doc)
    assert [f.symbol for f in found] == ["rank-dup:10"]
    assert "total order" in found[0].message


def test_lck002_docs_drift_stale_and_missing_rows(tmp_path):
    doc = _rank_doc(tmp_path, [("a.x", 11), ("c.z", 30)])
    found = check_lock_ranks(ranks={"a.x": 10, "b.y": 20}, doc_path=doc)
    assert {f.symbol for f in found} == {
        "docs:undocumented:b.y",            # in code, not in docs
        "docs:stale:c.z",                   # in docs, not in code
        "docs:rank-drift:a.x",              # 11 documented != 10 coded
    }


def test_lck002_missing_markers_and_missing_doc(tmp_path):
    bare = tmp_path / "bare.md"
    bare.write_text("no table here\n")
    found = check_lock_ranks(ranks={"a.x": 10}, doc_path=str(bare))
    assert [f.symbol for f in found] == ["docs:rank-table-markers"]
    found = check_lock_ranks(ranks={"a.x": 10},
                             doc_path=str(tmp_path / "absent.md"))
    assert [f.symbol for f in found] == ["docs:missing"]


def test_lck002_quiet_on_real_tree():
    assert check_lock_ranks() == []


# =====================================================================
# the gate: all rules + baseline mechanics + CLI exit codes
# =====================================================================
def test_run_all_rules_clean_on_real_tree():
    assert run_all_rules() == []


def test_fingerprints_are_line_independent():
    a = F.Finding("CAT001", "/x/src/repro/core/a.py", 10, "s.r", "m")
    b = F.Finding("CAT001", "/y/src/repro/core/a.py", 99, "s.r", "m2")
    assert a.fingerprint() == b.fingerprint() == \
        "CAT001:src/repro/core/a.py:s.r"


def test_baseline_suppresses_and_ratchets(tmp_path):
    live = F.Finding("CAT001", "src/repro/core/a.py", 1, "lib.rt", "m")
    path = str(tmp_path / "baseline.json")
    F.write_baseline([live], path, reason="known drift")
    baseline = F.load_baseline(path)
    assert baseline == {live.fingerprint(): "known drift"}

    gate = F.apply_baseline([live], baseline)
    assert gate.ok and [f.fingerprint() for f in gate.suppressed] == \
        [live.fingerprint()] and gate.stale == []

    # the finding stops firing -> its suppression turns stale, which is
    # a HARD failure (the ratchet's teeth): the fixed finding must take
    # its baseline row with it
    gate = F.apply_baseline([], baseline)
    assert not gate.ok and gate.stale == [live.fingerprint()]
    # ... unless the local escape hatch is explicit
    assert F.apply_baseline([], baseline, allow_stale=True).ok

    # a new, unbaselined finding fails the gate
    fresh = F.Finding("CAT002", "src/repro/core/b.py", 2, "o.r", "m")
    assert not F.apply_baseline([fresh], baseline).ok


def test_cli_static_gate_is_clean(capsys):
    assert analysis_main([]) == 0
    assert "repro.analysis: clean" in capsys.readouterr().out


def test_cli_stale_suppression_hard_fails_without_allow_stale(
        tmp_path, capsys):
    """The real tree is clean, so any baselined fingerprint is stale:
    the gate must fail on it, name it, and pass with --allow-stale."""
    dead = F.Finding("CAT001", "src/repro/core/a.py", 1, "gone.r", "m")
    path = str(tmp_path / "baseline.json")
    F.write_baseline([dead], path, reason="fixed long ago")

    assert analysis_main(["--baseline", path]) == 1
    out = capsys.readouterr().out
    assert "stale suppression" in out and dead.fingerprint() in out
    assert "--allow-stale" in out        # the message names the hatch

    assert analysis_main(["--baseline", path, "--allow-stale"]) == 0
    assert "1 stale suppression(s)" in capsys.readouterr().out

    assert analysis_main(["--baseline", path, "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["ok"] is False and payload["new"] == []
    assert payload["stale_suppressions"] == [dead.fingerprint()]


def test_cli_json_mode(capsys):
    assert analysis_main(["--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["ok"] is True and payload["new"] == []


def test_cli_lock_report_gate(tmp_path, capsys):
    clean = tmp_path / "clean.json"
    clean.write_text(json.dumps(
        {"locks": ["a", "b"], "edges": [
            {"from": "a", "to": "b", "count": 3, "site": "x.py:1"}],
         "cycles": [], "rank_inversions": []}))
    assert analysis_main(["--check-lock-report", str(clean)]) == 0
    assert "clean" in capsys.readouterr().out

    dirty = tmp_path / "dirty.json"
    dirty.write_text(json.dumps(
        {"locks": ["a", "b"], "edges": [],
         "cycles": [["a", "b", "a"]],
         "rank_inversions": [{"held": "b", "acquired": "a", "count": 1,
                              "site": "x.py:2"}]}))
    assert analysis_main(["--check-lock-report", str(dirty)]) == 1
    assert "VIOLATIONS" in capsys.readouterr().out

    assert analysis_main(["--check-lock-report",
                          str(tmp_path / "missing.json")]) == 2


# =====================================================================
# locktrace — the dynamic detector, unit level
# =====================================================================
def test_locktrace_detects_ab_ba_cycle():
    tr = locktrace.LockTrace()
    a = locktrace.TracedLock("t.A", trace=tr)
    b = locktrace.TracedLock("t.B", trace=tr)

    with a:
        with b:
            pass
    with b:
        with a:                   # the classic AB/BA inversion
            pass

    assert tr.cycles() == [["t.A", "t.B", "t.A"]]
    with pytest.raises(AssertionError, match="lock-order violations"):
        tr.assert_clean()


def test_locktrace_flags_rank_inversion_before_any_cycle():
    tr = locktrace.LockTrace()
    hi = locktrace.TracedLock("t.hi", rank=20, trace=tr)
    lo = locktrace.TracedLock("t.lo", rank=10, trace=tr)
    with hi:
        with lo:                  # lower rank acquired under higher
            pass
    p = tr.problems()
    assert p["cycles"] == []      # one-sided: no cycle yet
    assert [(i["held"], i["acquired"]) for i in p["rank_inversions"]] \
        == [("t.hi", "t.lo")]


def test_locktrace_records_wait_under_lock():
    tr = locktrace.LockTrace()
    outer = locktrace.TracedLock("t.outer", trace=tr)
    cv = locktrace.TracedCondition("t.cv", trace=tr)
    with outer:
        with cv:
            cv.wait(timeout=0.01)   # sleeps while still holding t.outer
    report = tr.report()
    assert [(w["held"], w["wait_on"])
            for w in report["waits_under_lock"]] == [("t.outer", "t.cv")]
    assert not report["cycles"] and not report["rank_inversions"]


def test_locktrace_ignores_rlock_reentry_and_clean_nesting():
    tr = locktrace.LockTrace()
    r = locktrace.TracedLock("t.R", inner=threading.RLock(), trace=tr)
    inner = locktrace.TracedLock("t.inner", trace=tr)
    with r:
        with r:                   # reentry: no self-edge
            with inner:
                pass
    assert ("t.R", "t.R") not in tr.edges
    assert ("t.R", "t.inner") in tr.edges
    tr.assert_clean()


def test_factories_are_plain_primitives_when_disabled(monkeypatch):
    monkeypatch.delenv(locktrace.ENV_FLAG, raising=False)
    assert not locktrace.enabled()
    lk = locktrace.make_lock("off.lock")
    assert type(lk) is type(threading.Lock())      # zero overhead
    assert isinstance(locktrace.make_condition("off.cv"),
                      threading.Condition)


def test_factories_are_traced_when_enabled(monkeypatch):
    monkeypatch.setenv(locktrace.ENV_FLAG, "1")
    lk = locktrace.make_lock("on.lock")
    cv = locktrace.make_condition("on.cv")
    assert isinstance(lk, locktrace.TracedLock)
    assert isinstance(cv, locktrace.TracedCondition)
    assert lk.rank is None        # unknown names are rank-exempt
    assert locktrace.make_rlock("engine.state").rank == \
        locktrace.LOCK_RANKS["engine.state"]


def test_documented_rank_table_names_every_core_lock():
    """Every dotted name core constructs a lock under must carry a rank
    (else the inversion check silently skips it)."""
    import re
    src_root = os.path.join(os.path.dirname(__file__), "..", "src",
                            "repro", "core")
    used = set()
    for dirpath, _dirs, files in os.walk(src_root):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            with open(os.path.join(dirpath, fname)) as f:
                used.update(re.findall(
                    r"locktrace\.make_(?:r?lock|condition)\(\s*"
                    r"['\"]([\w.]+)['\"]", f.read()))
    assert used, "core stopped using the locktrace factories?"
    assert used <= set(locktrace.LOCK_RANKS), \
        f"locks missing from LOCK_RANKS: {used - set(locktrace.LOCK_RANKS)}"


# =====================================================================
# the stress run: a fully traced engine + TCP server under real load
# =====================================================================
def test_stress_traced_engine_lock_graph_is_acyclic(monkeypatch):
    """Multi-thread multi-session chains over an engine whose every lock
    is instrumented, plus a socket client exercising the server and
    bridge locks — then the recorded acquisition graph must be acyclic
    and consistent with the documented rank order."""
    monkeypatch.setenv(locktrace.ENV_FLAG, "1")
    locktrace.TRACE.reset()

    # construct AFTER the flag is set: factories read it at build time
    from repro.core import AlchemistContext, AlchemistEngine
    from repro.core.engine import make_engine_mesh
    from repro.core.libraries import elemental
    from repro.core.server import AlchemistServer

    engine = AlchemistEngine(make_engine_mesh(1), scheduler_workers=4)
    engine.load_library("elemental", elemental)
    srv = AlchemistServer(engine=engine).start()
    errors = []

    def chains(ac, seed):
        try:
            for c in range(2):
                f1 = ac.call_async("elemental", "random_matrix",
                                   rows=24, cols=6, seed=seed + c)
                f2 = ac.call_async("elemental", "gram", A=f1["A"])
                f3 = ac.call_async("elemental", "multiply", A=f1["A"],
                                   B=f2["G"])
                assert f3.result()["C"].shape == (24, 6)
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    try:
        ctxs = [AlchemistContext(engine=engine, client_name=f"t{i}")
                for i in range(3)]
        ctxs.append(AlchemistContext(address=srv.address,
                                     client_name="socket"))
        threads = [threading.Thread(target=chains, args=(ac, 31 * i))
                   for i, ac in enumerate(ctxs)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for ac in ctxs:
            ac.stop()
    finally:
        srv.stop()

    assert not errors
    # tracing saw the real locks on both the engine and transport paths
    assert {"engine.state", "scheduler.cv"} <= locktrace.TRACE.names
    assert "wire.bridge" in locktrace.TRACE.names
    assert locktrace.TRACE.edges      # nesting actually happened
    # ... and the graph it recorded is deadlock-free and rank-ordered
    locktrace.TRACE.assert_clean()
    report = locktrace.TRACE.report()
    assert report["cycles"] == [] and report["rank_inversions"] == []

    locktrace.TRACE.reset()           # leave nothing for atexit to dump
