"""Backend ABI conformance suite: every cataloged routine served by both
backends from the same inputs, with numerically-close results and
identical output specs/layout metadata; layout negotiation (explicit
relayout, counted); backend selection over the ``configure`` endpoint;
cache isolation between backends; and the dist-sharding output
guarantee (no routine output drops the engine layout)."""
import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine, backends
from repro.core.context import AlchemistError
from repro.core.engine import make_engine_mesh
from repro.core.handles import MatrixHandle
from repro.core.libraries import elemental, mllib, skylark

RNG = np.random.RandomState(7)

# deterministic float32 inputs; SVD-family cases get a well-separated
# spectrum so singular vectors are stable across implementations
X = (RNG.randn(48, 12) @ np.diag(np.geomspace(8.0, 0.1, 12))).astype(
    np.float32)
Y = RNG.randn(48, 3).astype(np.float32)
SQ = (RNG.randn(16, 16) / 4.0).astype(np.float32)
POS = np.abs(RNG.randn(24, 10)).astype(np.float32)

BUNDLED = (("elemental", elemental), ("skylark", skylark),
           ("mllib", mllib))


@pytest.fixture(scope="module")
def rig():
    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=0)
    for name, module in BUNDLED:
        engine.load_library(name, module)
    ac_jax = AlchemistContext(engine=engine)            # engine default
    ac_ref = AlchemistContext(engine=engine, backend="reference")
    yield engine, ac_jax, ac_ref
    ac_jax.stop()
    ac_ref.stop()
    engine.shutdown()


def run_on(ac, library, routine, arrays, scalars):
    """Upload ``arrays``, invoke, fetch handle outputs; returns
    (raw result, fetched arrays, per-output (shape, dtype, layout))."""
    handles = {k: ac.send_matrix(v, dedup=False) for k, v in arrays.items()}
    res = ac.call(library, routine, **handles, **scalars)
    outs, meta = {}, {}
    for k, v in res.items():
        if isinstance(v, MatrixHandle):
            meta[k] = (tuple(v.shape), v.dtype, v.layout)
            outs[k] = ac.fetch(v).collect()
    return res, outs, meta


def run_both(rig, library, routine, arrays, scalars=None):
    _, ac_jax, ac_ref = rig
    scalars = scalars or {}
    _, out_j, meta_j = run_on(ac_jax, library, routine, arrays, scalars)
    _, out_r, meta_r = run_on(ac_ref, library, routine, arrays, scalars)
    # identical output specs/layout metadata — the ABI contract
    assert meta_j == meta_r, (library, routine, meta_j, meta_r)
    assert set(out_j) == set(out_r)
    return out_j, out_r


# ---------------------------------------------------------------------------
# ABI coverage
# ---------------------------------------------------------------------------
def test_every_cataloged_routine_registered_on_both_backends(rig):
    engine, _, _ = rig
    for backend in engine.backends.values():
        for lib, module in BUNDLED:
            for rn in module.ROUTINES:
                assert backend.supports(lib, rn), (backend.name, lib, rn)


def test_library_functions_are_catalog_only():
    """The engine never calls library functions; a direct call says so."""
    with pytest.raises(NotImplementedError, match="per-backend"):
        elemental.multiply(None, 1, 2)
    with pytest.raises(NotImplementedError, match="per-backend"):
        skylark.cg_solve(None, 1, 2)


def test_backend_registry_and_capabilities(rig):
    engine, _, _ = rig
    assert set(backends.available_backends()) >= {"jax", "reference"}
    caps_jax = engine.backends["jax"].capabilities()
    caps_ref = engine.backends["reference"].capabilities()
    assert caps_jax["supports_fusion"] and not caps_ref["supports_fusion"]
    assert "elemental.gram" in caps_jax["routines"]
    with pytest.raises(backends.BackendError, match="available"):
        backends.create_backend("nope")


# ---------------------------------------------------------------------------
# conformance: deterministic routines agree closely
# ---------------------------------------------------------------------------
def test_conformance_multiply(rig):
    out_j, out_r = run_both(rig, "elemental", "multiply",
                            {"A": X, "B": np.ascontiguousarray(X.T)})
    np.testing.assert_allclose(out_j["C"], out_r["C"], rtol=2e-4,
                               atol=1e-3)


def test_conformance_add(rig):
    out_j, out_r = run_both(rig, "elemental", "add", {"A": X, "B": X})
    np.testing.assert_allclose(out_j["C"], out_r["C"], rtol=1e-6)


def test_conformance_transpose(rig):
    out_j, out_r = run_both(rig, "elemental", "transpose", {"A": X})
    np.testing.assert_allclose(out_j["C"], out_r["C"], rtol=1e-6)
    np.testing.assert_allclose(out_j["C"], X.T, rtol=1e-6)


def test_conformance_replicate_cols(rig):
    out_j, out_r = run_both(rig, "elemental", "replicate_cols", {"A": X},
                            {"times": 3})
    np.testing.assert_allclose(out_j["A"], out_r["A"], rtol=1e-6)


def test_conformance_gram(rig):
    out_j, out_r = run_both(rig, "elemental", "gram", {"A": X})
    np.testing.assert_allclose(out_j["G"], out_r["G"], rtol=1e-3,
                               atol=1e-2)


def test_conformance_qr(rig):
    out_j, out_r = run_both(rig, "elemental", "qr", {"A": X})

    def canon(q, r):
        # fix the per-column sign ambiguity: make diag(R) positive
        s = np.sign(np.diag(r))
        s[s == 0] = 1.0
        return q * s, r * s[:, None]

    qj, rj = canon(out_j["Q"], out_j["R"])
    qr_, rr = canon(out_r["Q"], out_r["R"])
    np.testing.assert_allclose(qj, qr_, atol=2e-3)
    np.testing.assert_allclose(rj, rr, rtol=2e-3, atol=2e-3)


def _assert_svd_close(out_j, out_r, k, atol_v=2e-2):
    np.testing.assert_allclose(out_j["S"].ravel(), out_r["S"].ravel(),
                               rtol=2e-3)
    # singular vectors agree up to sign with a separated spectrum
    vj, vr = out_j["V"], out_r["V"]
    dots = np.abs(np.sum(vj * vr, axis=0))
    np.testing.assert_allclose(dots, np.ones(k), atol=atol_v)


def test_conformance_truncated_svd(rig):
    out_j, out_r = run_both(rig, "elemental", "truncated_svd", {"A": X},
                            {"k": 4})
    _assert_svd_close(out_j, out_r, 4)
    want = np.linalg.svd(X, compute_uv=False)[:4]
    np.testing.assert_allclose(out_j["S"].ravel(), want, rtol=1e-3)


def test_conformance_gram_svd(rig):
    out_j, out_r = run_both(rig, "elemental", "gram_svd", {"A": X},
                            {"k": 4})
    _assert_svd_close(out_j, out_r, 4)


def test_conformance_randomized_svd(rig):
    out_j, out_r = run_both(rig, "elemental", "randomized_svd", {"A": X},
                            {"k": 3, "power_iters": 3})
    # different PRNGs sketch differently; with power iteration both
    # recover the well-separated top singular values
    want = np.linalg.svd(X, compute_uv=False)[:3]
    np.testing.assert_allclose(out_j["S"].ravel(), want, rtol=1e-2)
    np.testing.assert_allclose(out_r["S"].ravel(), want, rtol=1e-2)


def test_conformance_cg_solve(rig):
    out_j, out_r = run_both(rig, "skylark", "cg_solve",
                            {"X": X, "Y": Y},
                            {"lam": 1e-3, "max_iters": 400, "tol": 1e-10})
    np.testing.assert_allclose(out_j["W"], out_r["W"], atol=1e-4)
    want = np.linalg.solve(
        X.T.astype(np.float64) @ X + 48 * 1e-3 * np.eye(12),
        X.T.astype(np.float64) @ Y)
    np.testing.assert_allclose(out_j["W"], want, atol=1e-3)


def test_conformance_random_matrix_distribution(rig):
    """Seeded creation: cross-backend bitwise equality is not promised
    (numpy cannot replay jax's counter PRNG) — the contract is the spec
    (shape/dtype/layout, asserted by run_both) plus the distribution."""
    out_j, out_r = run_both(rig, "elemental", "random_matrix", {},
                            {"rows": 256, "cols": 64, "seed": 3,
                             "scale": 2.0})
    for out in (out_j["A"], out_r["A"]):
        assert out.shape == (256, 64) and out.dtype == np.float32
        assert abs(float(out.mean())) < 0.1
        assert abs(float(out.std()) - 2.0) < 0.1


def test_conformance_random_features_distribution(rig):
    out_j, out_r = run_both(rig, "skylark", "random_features", {"X": X},
                            {"rf_dim": 64, "bandwidth": 2.0, "seed": 1})
    bound = np.sqrt(2.0 / 64) + 1e-6
    for out in (out_j["Z"], out_r["Z"]):
        assert out.shape == (48, 64)
        assert float(np.abs(out).max()) <= bound
    assert abs(float(out_j["Z"].std()) - float(out_r["Z"].std())) < 0.05


def test_conformance_nmf_invariants(rig):
    out_j, out_r = run_both(rig, "skylark", "nmf", {"A": POS},
                            {"k": 4, "max_iters": 60})
    for out in (out_j, out_r):
        assert (out["W"] >= 0).all() and (out["H"] >= 0).all()
    resid_j, _, _ = _nmf_resid(out_j)
    resid_r, _, _ = _nmf_resid(out_r)
    assert abs(resid_j - resid_r) < 0.15


def _nmf_resid(out):
    w, h = out["W"], out["H"]
    resid = float(np.linalg.norm(POS - w @ h) / np.linalg.norm(POS))
    return resid, w, h


def test_conformance_mllib_shared_baseline(rig):
    """mllib is backend-invariant by design (shared row-partitioned host
    math): both backends must agree to float precision, and report the
    same BSP accounting."""
    res_j, out_j, meta_j = run_on(rig[1], "mllib", "cg_solve",
                                  {"X": X, "Y": Y}, {"lam": 1e-3})
    res_r, out_r, meta_r = run_on(rig[2], "mllib", "cg_solve",
                                  {"X": X, "Y": Y}, {"lam": 1e-3})
    assert meta_j == meta_r
    np.testing.assert_allclose(out_j["W"], out_r["W"], atol=1e-5)
    assert res_j["bsp_rounds"] == res_r["bsp_rounds"]
    res_j, out_j, _ = run_on(rig[1], "mllib", "truncated_svd", {"A": X},
                             {"k": 3})
    res_r, out_r, _ = run_on(rig[2], "mllib", "truncated_svd", {"A": X},
                             {"k": 3})
    np.testing.assert_allclose(out_j["S"], out_r["S"], rtol=1e-5)
    assert res_j["lanczos_iters"] == res_r["lanczos_iters"]


# ---------------------------------------------------------------------------
# layouts: real tags, negotiation, dist-sharded outputs
# ---------------------------------------------------------------------------
def test_uploads_and_outputs_carry_real_layouts(rig):
    engine, ac, _ = rig
    al = ac.send_matrix(SQ, dedup=False)
    assert al.handle.layout == "rowblock"
    assert engine.layout(al.handle) == "rowblock"
    out = ac.call("elemental", "transpose", A=al)["C"]
    assert out.layout == "rowblock"


def test_routine_outputs_land_in_engine_dist_sharding(rig):
    """The satellite fix: transpose/add/multiply must not return
    host-materialized arrays that drop the distributed sharding — every
    output goes through the engine's dist-sharding put path."""
    engine, ac, ac_ref = rig
    al = ac.send_matrix(SQ, dedup=False)
    for routine, kwargs in (("transpose", {"A": al}),
                            ("add", {"A": al, "B": al}),
                            ("multiply", {"A": al, "B": al})):
        for ctx in (ac, ac_ref):
            a = ctx.send_matrix(SQ, dedup=False)
            kw = {k: a for k in kwargs}
            res = ctx.call("elemental", routine, **kw)
            arr = engine.get(res["C"], session=ctx.session)
            assert arr.sharding == engine.dist_sharding(arr.shape), \
                (routine, ctx.backend)


def test_foreign_layout_triggers_counted_relayout(rig):
    """An operand in a layout the implementation does not accept gets an
    explicit relayout step, charged to the task's accounting."""
    engine, ac, _ = rig
    import jax.numpy as jnp

    arr = jnp.asarray(SQ)
    h = engine.put(arr, session=ac.session, layout="block2d")
    before = engine.task_log.stats()
    res = ac.call("elemental", "gram", A=ac.wrap(h))
    after = engine.task_log.stats()
    assert after["relayouts"] == before["relayouts"] + 1
    assert after["relayout_bytes"] == before["relayout_bytes"] + SQ.nbytes
    g = ac.fetch(res["G"]).collect()
    np.testing.assert_allclose(g, SQ.T @ SQ, rtol=1e-3, atol=1e-3)


def test_accepted_layouts_do_not_relayout(rig):
    engine, ac, _ = rig
    al = ac.send_matrix(SQ, dedup=False)          # rowblock: accepted
    before = engine.task_log.stats()["relayouts"]
    ac.call("elemental", "gram", A=al)
    assert engine.task_log.stats()["relayouts"] == before


# ---------------------------------------------------------------------------
# backend selection (configure endpoint / context kwarg)
# ---------------------------------------------------------------------------
def test_configure_selects_backend_per_session(rig):
    engine, ac_jax, ac_ref = rig
    assert ac_jax.backend == "jax"
    assert ac_ref.backend == "reference"
    # per-session: the jax session is unaffected by the reference one
    sess = engine.session(ac_ref.session)
    assert sess.backend == "reference"
    assert engine.session(ac_jax.session).backend in ("", "jax")


def test_configure_rejects_unknown_backend_and_options(rig):
    engine, ac, _ = rig
    with pytest.raises(AlchemistError, match="available"):
        ac.configure(backend="cuda")
    with pytest.raises(AlchemistError, match="unknown configure option"):
        from repro.core import protocol
        res = protocol.decode_result(engine.configure(
            protocol.encode_configure(protocol.Configure(
                session=ac.session, options={"turbo": True}))))
        raise AlchemistError(res.error)
    # the failed attempts changed nothing
    assert ac.backend == "jax"


def test_configure_error_applies_nothing(rig):
    """A configure request that errors must be atomic: a valid backend
    option in the same message as a bad fusion option changes nothing."""
    engine, ac, _ = rig
    from repro.core import protocol
    res = protocol.decode_result(engine.configure(
        protocol.encode_configure(protocol.Configure(
            session=ac.session,
            options={"backend": "reference", "fusion": "yes"}))))
    assert "fusion" in res.error
    sess = engine.session(ac.session)
    assert sess.backend in ("", "jax") and sess.fusion is True


def test_bad_backend_at_construction_leaks_no_session():
    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=0)
    try:
        before = len(engine.sessions())
        with pytest.raises(AlchemistError, match="available"):
            AlchemistContext(engine=engine, backend="nope")
        assert len(engine.sessions()) == before
    finally:
        engine.shutdown()


def test_configure_fusion_toggle_roundtrip(rig):
    engine, _, _ = rig
    ac = AlchemistContext(engine=engine, fusion=False)
    try:
        assert engine.session(ac.session).fusion is False
        assert ac.configure(fusion=True)["fusion"] is True
    finally:
        ac.stop()


def test_engine_rejects_unknown_default_backend():
    with pytest.raises(backends.BackendError, match="available"):
        AlchemistEngine(make_engine_mesh(1), backend="nope")


def test_system_session_cannot_be_configured(rig):
    engine, _, _ = rig
    from repro.core import protocol
    res = protocol.decode_result(engine.configure(
        protocol.encode_configure(protocol.Configure(
            session=0, options={"backend": "jax"}))))
    assert "system session" in res.error


# ---------------------------------------------------------------------------
# cache isolation between backends
# ---------------------------------------------------------------------------
def test_cache_keys_are_backend_scoped():
    """A jax-computed result must never be served to a reference
    session (recomputing with the other implementation is its point) —
    but each backend hits its own cache."""
    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=64)
    engine.load_library("elemental", elemental)
    ac_j = AlchemistContext(engine=engine)
    ac_r = AlchemistContext(engine=engine, backend="reference")
    try:
        a = RNG.randn(16, 4).astype(np.float32)
        r1 = ac_j.call("elemental", "gram", A=ac_j.send_matrix(a))
        assert not r1["_cache_hit"]
        r2 = ac_j.call("elemental", "gram", A=ac_j.send_matrix(a))
        assert r2["_cache_hit"]                     # same backend: hit
        r3 = ac_r.call("elemental", "gram", A=ac_r.send_matrix(a))
        assert not r3["_cache_hit"]                 # other backend: miss
        r4 = ac_r.call("elemental", "gram", A=ac_r.send_matrix(a))
        assert r4["_cache_hit"]
    finally:
        ac_j.stop()
        ac_r.stop()
        engine.shutdown()


def test_legacy_ali_library_runs_on_any_backend():
    """Unregistered third-party routines dispatch through the ABI's
    legacy wrapper on every backend — old libraries keep working."""
    def doubled(eng, A):
        import jax.numpy as jnp
        return {"C": eng.put(jnp.asarray(eng.get(A)) * 2.0)}

    class _Lib:
        ROUTINES = {"doubled": doubled}

    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=0)
    engine.load_library("thirdparty", _Lib)
    for backend in ("jax", "reference"):
        ac = AlchemistContext(engine=engine, backend=backend)
        try:
            al = ac.send_matrix(SQ, dedup=False)
            out = ac.call("thirdparty", "doubled", A=al)
            got = ac.fetch(out["C"]).collect()
            np.testing.assert_allclose(got, 2.0 * SQ, rtol=1e-6)
        finally:
            ac.stop()
    engine.shutdown()


def test_backend_registries_have_identical_catalog_metadata():
    """The parity invariant CAT001-004 gates in CI, asserted directly:
    both bundled backends serve the same (library, routine) set with
    matching fusible/bucketable flags and shape-rule coverage — the
    flags describe the routine, so which backend executes must never
    change what fuses or what warmup can bucket."""
    jax_be = backends.create_backend("jax")
    ref_be = backends.create_backend("reference")
    assert jax_be.routines() == ref_be.routines()
    for lib, rt in jax_be.routines():
        a = jax_be.routine_impl(lib, rt)
        b = ref_be.routine_impl(lib, rt)
        assert a.fusible == b.fusible, (lib, rt)
        assert a.bucketable == b.bucketable, (lib, rt)
        assert (a.out_shapes is None) == (b.out_shapes is None), (lib, rt)
        if a.bucketable:
            assert a.out_shapes is not None, (lib, rt)
