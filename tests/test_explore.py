"""The deterministic interleaving explorer, end to end.

Four claims, each load-bearing:

* **it finds bugs** — the sweep over ``fixture_injected`` (a seeded
  release-vs-finish race) discovers the violating schedules;
* **it replays them** — re-running a discovered schedule reproduces the
  identical violations, twice, byte for byte (the determinism the
  ``--replay`` workflow depends on);
* **the real windows are closed** — bounded sweeps over the scheduler,
  submit-vs-disconnect, and reservation-vs-disconnect scenarios complete
  with zero monitor violations and zero failed post-conditions;
* **the oracle has teeth** — reverting ``engine.reserve_upload`` to its
  pre-fix shape (grant without the liveness re-check) makes the same
  sweep fail with the illegal RELEASED→ACTIVE edge, reproducibly.

Plus direct, schedule-free regressions for the two races the explorer
found, pinned at the exact historical window via the same hooks the
scenarios use.
"""
import numpy as np
import pytest

from repro.analysis import explore, statemachine
from repro.analysis.explore import next_schedule, run_schedule, sweep


# =====================================================================
# DFS mechanics
# =====================================================================
def test_next_schedule_bumps_deepest_untried_branch():
    assert next_schedule([(0, 2), (0, 1), (0, 3)]) == [0, 0, 1]
    assert next_schedule([(0, 2), (2, 3)]) == [0] * 0 + [1]  # deepest done
    assert next_schedule([(1, 2), (2, 3)]) is None           # exhausted
    assert next_schedule([(0, 1)]) is None                   # no branching
    assert next_schedule([]) is None


def test_controller_choice_order_is_seed_stable():
    """Same seed => same parked-thread ordering; the recorded choices of
    two identical runs must match exactly."""
    a = run_schedule("fixture_injected", seed=3, schedule=[])
    b = run_schedule("fixture_injected", seed=3, schedule=[])
    assert a["choices"] == b["choices"] and a["trail"] == b["trail"]


# =====================================================================
# the explorer's own teeth: the seeded fixture bug
# =====================================================================
def test_sweep_finds_the_injected_fixture_bug():
    rep = sweep("fixture_injected", seed=0, max_schedules=32)
    assert rep["exhausted"] and rep["wedged"] == 0
    assert rep["violating_schedules"], "the seeded bug went undetected"
    assert rep["ok"]                      # expect == "violation"
    kinds = {v["kind"] for r in rep["results"] for v in r["violations"]}
    assert "illegal-edge" in kinds


def test_replay_reproduces_identical_violations():
    rep = sweep("fixture_injected", seed=0, max_schedules=32)
    schedule = rep["violating_schedules"][0]
    runs = [run_schedule("fixture_injected", seed=0, schedule=schedule)
            for _ in range(2)]
    assert runs[0]["violations"], "replayed schedule lost the violation"
    assert runs[0]["violations"] == runs[1]["violations"]
    assert runs[0]["trail"] == runs[1]["trail"]
    # and a different seed renumbers choices but the bug is still found
    rep2 = sweep("fixture_injected", seed=17, max_schedules=32)
    assert rep2["violating_schedules"] and rep2["ok"]


def test_cli_sweep_and_replay_roundtrip(tmp_path, capsys):
    out = tmp_path / "report.json"
    assert explore.main(["--scenario", "fixture_injected",
                         "--schedules", "32",
                         "--json", str(out)]) == 0
    text = capsys.readouterr().out
    assert "violating" in text and out.exists()
    # replay the first printed schedule and expect the violation again
    line = next(l for l in text.splitlines() if "--replay" in l)
    sched = line.split("--replay", 1)[1].strip()
    assert explore.main(["--scenario", "fixture_injected",
                         "--replay", sched]) == 0
    assert "illegal-edge" in capsys.readouterr().out


# =====================================================================
# the real race windows sweep clean on the fixed engine
# =====================================================================
@pytest.mark.parametrize("scenario,budget", [
    ("submit_vs_release", 8),
    ("claim_chain_vs_hazard", 12),
    ("disconnect_vs_midtask", 20),
    ("throttle_release_vs_commit", 30),
])
def test_real_window_sweeps_are_clean(scenario, budget):
    rep = sweep(scenario, seed=0, max_schedules=budget)
    assert rep["ok"], (rep["violating_schedules"], rep["failed_checks"])
    assert rep["violating_schedules"] == []
    assert rep["failed_checks"] == []
    assert rep["wedged"] < rep["schedules_run"]   # not all wedged


# =====================================================================
# oracle teeth on a real engine: revert the fix, the sweep must fail
# =====================================================================
def test_sweep_catches_prefix_reservation_race(monkeypatch):
    """``engine.reserve_upload`` without the locked liveness re-check
    (the pre-fix shape: grant, note, return) lets a disconnect landing
    inside the admission window revive the forgotten session's
    reservation row. The throttle sweep must catch it — as the illegal
    RELEASED→ACTIVE edge — and the failing schedule must replay."""
    from repro.core.engine import AlchemistEngine

    def naive_reserve(self, session, nbytes):
        if self.admission is None:
            return None
        denial = self.admission.reserve_upload(
            session, nbytes, weight=self._session_weight(session))
        if denial is None and self._stm.enabled:
            self._stm.note("reservation", (self._stm_dom, session),
                           "ACTIVE", site="reserve_upload")
        return denial

    monkeypatch.setattr(AlchemistEngine, "reserve_upload", naive_reserve)
    rep = sweep("throttle_release_vs_commit", seed=0, max_schedules=30)
    assert not rep["ok"], "sweep failed to catch the reverted fix"
    assert rep["violating_schedules"]
    kinds = {v["kind"] for r in rep["results"] for v in r["violations"]}
    assert "illegal-edge" in kinds
    # deterministic replay of the discovered bug
    res = run_schedule("throttle_release_vs_commit", seed=0,
                       schedule=rep["violating_schedules"][0])
    assert any(v["kind"] == "illegal-edge" and
               "RELEASED -> ACTIVE" in v["detail"]
               for v in res["violations"]), res["violations"]


# =====================================================================
# direct regressions for the two races the explorer found
# =====================================================================
def _engine(**kw):
    from repro.core.engine import AlchemistEngine
    kw.setdefault("scheduler_workers", 1)
    kw.setdefault("cache_entries", 0)
    return AlchemistEngine(**kw)


def test_submit_rejects_disconnect_inside_the_window(monkeypatch):
    """Race fix 1, pinned: disconnect completing between submit's
    unlocked session check and the task mint must yield a clean
    UnknownSession error on the wire — no task minted into the freed
    namespace."""
    from repro.core import protocol as P
    from repro.core.engine import ENGINE_LIBRARY
    monkeypatch.setenv(statemachine.ENV_FLAG, "1")
    statemachine.TRACE.reset()
    eng = _engine(qos=True)
    try:
        sess = eng.connect("victim")
        real_hazards = eng._hazards

        def hazards_then_disconnect(cmd):
            res = real_hazards(cmd)
            eng.disconnect(sess.id)     # lands exactly in the window
            return res
        eng._hazards = hazards_then_disconnect

        cmd = P.Command(library=ENGINE_LIBRARY, routine="qos_stats",
                        session=sess.id, args={})
        r = P.decode_result(eng.submit(P.encode_command(cmd)))
        assert r.error and "UnknownSession" in r.error
        assert not r.task
        assert sess.id not in eng._sessions
        assert eng.scheduler.session_depth(sess.id) == 0
    finally:
        eng.shutdown()
    statemachine.TRACE.assert_clean()
    statemachine.TRACE.reset()


def test_reserve_upload_compensates_when_session_vanishes(monkeypatch):
    """Race fix 2, pinned: a disconnect landing between the admission
    grant and the engine's liveness re-check must turn the grant into a
    denial and leave zero in-flight bytes (the compensating release)."""
    monkeypatch.setenv(statemachine.ENV_FLAG, "1")
    statemachine.TRACE.reset()
    eng = _engine(qos=True, qos_quotas={"max_inflight_bytes": 1 << 20})
    try:
        sess = eng.connect("vanisher")
        real_reserve = eng.admission.reserve_upload

        def reserve_then_disconnect(session, nbytes, weight=1.0):
            res = real_reserve(session, nbytes, weight=weight)
            eng.disconnect(sess.id)     # lands exactly in the window
            return res
        eng.admission.reserve_upload = reserve_then_disconnect

        denial = eng.reserve_upload(sess.id, 4096)
        assert denial is not None and "disconnecting" in denial[0]
        assert eng.admission.inflight_bytes(sess.id) == 0
        assert sess.id not in eng._sessions
    finally:
        eng.shutdown()
    statemachine.TRACE.assert_clean()
    statemachine.TRACE.reset()


def test_server_aborts_open_uploads_on_client_disconnect(monkeypatch):
    """Hardening pinned at the server layer: a handshake DISCONNECT with
    a chunked upload still open aborts the stream and returns its
    reserved bytes before the engine forgets the session — the monitor
    sees OPEN → ABORTED, never an OPEN stream outliving its session."""
    import msgpack
    from repro.core import protocol, wire
    from repro.core.server import AlchemistServer
    monkeypatch.setenv(statemachine.ENV_FLAG, "1")
    statemachine.TRACE.reset()
    eng = _engine(qos=True, qos_quotas={"max_inflight_bytes": 1 << 20})
    srv = AlchemistServer(engine=eng).start()
    try:
        bridge = wire.SocketBridge(srv.address)
        reply = protocol.decode_result(bridge.handshake(
            protocol.encode_handshake(protocol.Handshake(
                action=protocol.CONNECT, client="aborter"))))
        sid = reply.values["session"]
        begin = msgpack.packb({"shape": [64, 8], "dtype": "float32",
                               "session": sid, "name": "half-open",
                               "num_chunks": 4, "single": False})
        with bridge._lock:
            bridge._send("upload", wire.FRAME_UPLOAD_BEGIN, begin)
            _, raw = bridge._recv("upload")
        uid = protocol.decode_result(raw).values["upload"]
        chunk = np.ones((16, 8), np.float32)
        bridge._send("upload", wire.FRAME_UPLOAD_CHUNK, msgpack.packb(
            {"upload": uid, "seq": 0, "array": wire.pack_ndarray(chunk)}))
        assert eng.admission.inflight_bytes(sid) > 0
        # clean client-requested DISCONNECT while the stream is OPEN
        bridge.handshake(protocol.encode_handshake(protocol.Handshake(
            action=protocol.DISCONNECT, session=sid)))
        assert eng.admission.inflight_bytes(sid) == 0
        assert sid not in eng._sessions
        bridge.close()
    finally:
        srv.stop()
        eng.shutdown()
    statemachine.TRACE.assert_clean()
    statemachine.TRACE.reset()
