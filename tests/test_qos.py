"""Multi-tenant QoS: fair-share dispatch, admission control, and
end-to-end backpressure (core/qos; ROADMAP item 2).

The policy/admission unit tests poke ``core/qos`` directly; the
integration tests run — like every protocol suite — over both the
in-memory bridge and real TCP (see conftest ``_BRIDGED_SUITES``), so the
typed busy error and its ``retry_after_s`` hint are proven to survive
the socket crossing.
"""
import collections
import threading
import types

import numpy as np
import pytest

from repro.core import AlchemistBusyError, AlchemistContext, \
    AlchemistEngine, AlchemistError
from repro.core.engine import make_engine_mesh
from repro.core.libraries import elemental
from repro.core.qos import AdmissionController, FairShareQueue, \
    FifoReadyQueue, QuotaConfig


def _task(tid, session, price=0.0, exec_s=0.0, wait_s=0.0):
    return types.SimpleNamespace(id=tid, session=session, price=price,
                                 exec_s=exec_s, wait_s=wait_s)


def _qos_engine(**kw):
    kw.setdefault("qos", True)
    return AlchemistEngine(make_engine_mesh(2), scheduler_workers=1, **kw)


def _context(engine, **kw):
    ac = AlchemistContext(engine=engine, **kw)
    ac.register_library("elemental", elemental)
    return ac


# ---------------------------------------------------------------------------
# policy unit tests
# ---------------------------------------------------------------------------
class TestFifoIdentity:
    def test_order_matches_plain_deque(self):
        q = FifoReadyQueue()
        ref = collections.deque()
        for tid in [5, 3, 9, 1]:
            q.push(_task(tid, session=tid % 2))
            ref.append(tid)
        assert len(q) == 4 and bool(q)
        assert [q.pop() for _ in range(4)] == list(ref)
        assert len(q) == 0 and not q

    def test_qos_hooks_are_noops(self):
        q = FifoReadyQueue()
        q.push(_task(1, session=7))
        q.task_done(_task(1, session=7, exec_s=3.0))
        q.set_weight(7, 100.0)
        assert q.should_yield(7) is False
        q.forget_session(99)
        assert q.pop() == 1


class TestFairShare:
    def test_light_tenant_wins_against_expensive_queue(self):
        # heavy session 1 queues pricey tasks; light session 2 cheap ones.
        # After the tie-broken first pick, the light tenant should land
        # several dispatches before the heavy one's clock comes back down.
        q = FairShareQueue()
        for tid in (10, 11, 12):
            q.push(_task(tid, session=1, price=1.0))
        for tid in (20, 21, 22):
            q.push(_task(tid, session=2, price=0.1))
        order = [q.pop() for _ in range(6)]
        # vtime tie at 0 -> session 1 (lower id) pops once, charging 1.0;
        # session 2 then drains fully (0.1 steps) before session 1 again
        assert order == [10, 20, 21, 22, 11, 12]

    def test_weights_scale_the_share(self):
        q = FairShareQueue()
        q.set_weight(1, 2.0)
        q.set_weight(2, 1.0)
        for tid in range(100, 110):
            q.push(_task(tid, session=1, price=1.0))
        for tid in range(200, 210):
            q.push(_task(tid, session=2, price=1.0))
        picks = [q.pop() for _ in range(9)]
        share_1 = sum(1 for t in picks if t < 200)
        # equal prices, weight 2:1 -> session 1 gets ~2/3 of the picks
        assert share_1 == 6

    def test_idle_session_earns_no_credit(self):
        q = FairShareQueue()
        q.push(_task(1, session=1, price=1.0))
        assert q.pop() == 1               # clock -> 0, vtime(1) -> 1.0
        q.push(_task(2, session=1, price=1.0))
        assert q.pop() == 2               # clock -> 1.0, vtime(1) -> 2.0
        # session 2 was idle the whole time: its vtime floors to the
        # clock (1.0), not 0 — it gets the next pick but cannot burst
        # arbitrarily on a stale low clock
        q.push(_task(3, session=2, price=1.0))
        assert q._vtime[2] == pytest.approx(1.0)

    def test_task_done_reconciles_debt(self):
        q = FairShareQueue()
        q.push(_task(1, session=1, price=0.1))
        q.pop()
        v_after_charge = q._vtime[1]
        # measured exec 10x the estimate: the difference lands as debt
        q.task_done(_task(1, session=1, price=0.1, exec_s=1.0))
        assert q._vtime[1] == pytest.approx(v_after_charge + 0.9)

    def test_task_done_unknown_task_is_noop(self):
        q = FairShareQueue()
        q.task_done(_task(42, session=1, exec_s=9.0))  # claimed-chain case
        assert q._vtime == {}

    def test_should_yield_only_for_trailing_ready_work(self):
        q = FairShareQueue(yield_threshold_s=0.05)
        q.push(_task(1, session=1, price=1.0))
        q.pop()                           # vtime(1)=1.0, nothing else ready
        assert not q.should_yield(1)      # no other session has work
        q.push(_task(2, session=2, price=0.1))
        assert q.should_yield(1)          # session 2 ready, trails by ~1.0
        assert not q.should_yield(2)      # the trailing side never yields

    def test_forget_session_drops_queue_and_clock(self):
        q = FairShareQueue()
        q.push(_task(1, session=1, price=1.0))
        q.push(_task(2, session=2, price=1.0))
        q.forget_session(1)
        assert len(q) == 1
        assert q.depths() == {2: 1}
        assert q.pop() == 2


# ---------------------------------------------------------------------------
# admission unit tests
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_queue_depth_quota(self):
        ctl = AdmissionController(QuotaConfig(max_queue_depth=2))
        assert ctl.admit_submit(1, 1.0, queue_depth=1,
                                resident_bytes=0) is None
        denial = ctl.admit_submit(1, 1.0, queue_depth=2, resident_bytes=0)
        assert denial is not None
        reason, retry = denial
        assert "queue depth" in reason
        assert 0.05 <= retry <= 5.0

    def test_resident_bytes_quota(self):
        ctl = AdmissionController(QuotaConfig(max_resident_bytes=100))
        assert ctl.admit_submit(1, 1.0, queue_depth=0,
                                resident_bytes=100) is None
        denial = ctl.admit_submit(1, 1.0, queue_depth=0,
                                  resident_bytes=101)
        assert denial is not None and "resident" in denial[0]

    def test_no_quota_admits_everything(self):
        ctl = AdmissionController()
        assert ctl.admit_submit(1, 1.0, queue_depth=10 ** 6,
                                resident_bytes=10 ** 15) is None

    def test_per_session_override(self):
        ctl = AdmissionController(QuotaConfig(max_queue_depth=10))
        ctl.set_quota(2, {"max_queue_depth": 1})
        assert ctl.admit_submit(1, 1.0, queue_depth=5,
                                resident_bytes=0) is None
        assert ctl.admit_submit(2, 1.0, queue_depth=5,
                                resident_bytes=0) is not None
        assert ctl.quota_for(2).max_queue_depth == 1
        assert ctl.quota_for(1).max_queue_depth == 10

    def test_upload_reserve_release(self):
        ctl = AdmissionController(QuotaConfig(max_inflight_bytes=1000))
        assert ctl.reserve_upload(1, 600) is None
        assert ctl.inflight_bytes(1) == 600
        denial = ctl.reserve_upload(1, 600)
        assert denial is not None and "in-flight" in denial[0]
        assert ctl.inflight_bytes(1) == 600   # nothing reserved on denial
        ctl.release_upload(1, 600)
        assert ctl.inflight_bytes(1) == 0
        assert ctl.reserve_upload(1, 1000) is None

    def test_forget_session_reclaims_reservations(self):
        ctl = AdmissionController(QuotaConfig(max_inflight_bytes=1000))
        ctl.reserve_upload(1, 800)
        ctl.set_quota(1, {"max_queue_depth": 1})
        assert ctl.forget_session(1) == 800
        assert ctl.inflight_bytes(1) == 0
        assert ctl.quota_for(1).max_queue_depth is None

    def test_retry_hint_scales_with_depth(self):
        hint = AdmissionController._retry_hint
        assert hint(0, 0.0) == pytest.approx(0.05)
        assert hint(4, 0.5) == pytest.approx(2.0)
        assert hint(10 ** 6, 10.0) == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# engine integration (runs over both bridges)
# ---------------------------------------------------------------------------
class TestEngineQos:
    def test_over_quota_submit_raises_typed_busy_error(self):
        eng = _qos_engine(qos_quotas={"max_queue_depth": 1})
        try:
            ac = _context(eng, busy_retries=0)
            el = ac.library("elemental")
            a = ac.send_matrix(np.random.default_rng(0).normal(
                size=(32, 8)))
            eng.scheduler.pause()
            try:
                f1 = el.transpose(A=a)    # depth 0 -> admitted, queues
                with pytest.raises(AlchemistBusyError) as ei:
                    el.gram(A=a)          # depth 1 -> at quota, denied
                assert ei.value.retry_after_s > 0
                assert "queue depth" in str(ei.value)
            finally:
                eng.scheduler.resume()
            assert f1.to_numpy().shape == (8, 32)
            stats = eng.qos_stats()
            assert stats["rejected"] >= 1 and stats["admitted"] >= 1
            # the same accounting is wire-reachable as an engine builtin
            wire_stats = ac.call("_engine", "qos_stats")
            assert wire_stats["enabled"] is True
            assert wire_stats["rejected"] >= 1
            assert "ready_depths" in wire_stats
            ac.stop()
        finally:
            eng.shutdown()

    def test_busy_submit_retries_until_capacity_frees(self):
        eng = _qos_engine(qos_quotas={"max_queue_depth": 1})
        try:
            ac = _context(eng, busy_retries=8)
            el = ac.library("elemental")
            a = ac.send_matrix(np.ones((16, 4)))
            eng.scheduler.pause()
            f1 = el.transpose(A=a)
            t = threading.Timer(0.15, eng.scheduler.resume)
            t.start()
            try:
                # blocks in the client backoff loop until the timer
                # resumes the scheduler and the queue drains
                f2 = el.gram(A=a)
            finally:
                t.join()
            assert f1.to_numpy().shape == (4, 16)
            assert f2.to_numpy().shape == (4, 4)
            ac.stop()
        finally:
            eng.shutdown()

    def test_upload_backpressure_over_socket(self, bridge_mode):
        # in-flight upload reservations are the *server's* staging
        # quota: the in-memory bridge never stages, so only the socket
        # run exercises them
        if bridge_mode != "socket":
            pytest.skip("upload staging backpressure is wire-only")
        eng = _qos_engine(qos_quotas={"max_inflight_bytes": 1024})
        try:
            ac = _context(eng)
            with pytest.raises(AlchemistBusyError) as ei:
                ac.send_matrix(np.ones((64, 64)))   # 32 KiB > 1 KiB quota
            assert ei.value.retry_after_s > 0
            assert eng.qos_stats()["throttled"] >= 1
            # nothing leaked: a small upload still fits afterwards
            small = ac.send_matrix(np.ones((4, 4)))
            assert small.to_numpy().shape == (4, 4)
            assert eng.admission.inflight_bytes(ac.session) == 0
            ac.stop()
        finally:
            eng.shutdown()

    def test_fair_share_preempts_heavy_tenant(self):
        # one worker: the heavy SVD holds it while the light tenant's
        # task sits ready — the iteration-boundary yield_check must fire
        eng = _qos_engine(qos_yield_threshold_s=1e-6)
        try:
            heavy = _context(eng, backend="reference")
            light = _context(eng, backend="reference")
            el_h = heavy.library("elemental")
            el_l = light.library("elemental")
            a = heavy.send_matrix(np.random.default_rng(1).normal(
                size=(512, 64)))
            b = light.send_matrix(np.ones((16, 4)))
            eng.scheduler.pause()
            # the register_library barrier tasks above left the two
            # sessions at unequal virtual times; zero the clocks (under
            # the scheduler lock, like every policy mutation) so the pop
            # order below is deterministic: the SVD dispatches first and
            # the light task waits ready behind it
            with eng.scheduler._cv:
                eng._qos_policy._vtime.clear()
                eng._qos_policy._clock = 0.0
            svd = el_h.truncated_svd(A=a, k=8)
            g = el_l.gram(A=b)
            eng.scheduler.resume()
            assert svd[1].to_numpy().shape == (8,)
            assert g.to_numpy().shape == (4, 4)
            assert eng.qos_stats()["preempted"] >= 1
            heavy.stop()
            light.stop()
        finally:
            eng.shutdown()

    def test_configure_weight_and_quotas_echoed(self):
        eng = _qos_engine()
        try:
            ac = _context(eng)
            eff = ac.configure(weight=3.0,
                               quotas={"max_queue_depth": 7})
            assert eff["weight"] == pytest.approx(3.0)
            assert eff["quotas"]["max_queue_depth"] == 7
            assert eff["quotas"]["max_inflight_bytes"] is None
            ac.stop()
        finally:
            eng.shutdown()

    def test_configure_rejects_bad_qos_options(self):
        eng = _qos_engine()
        try:
            ac = _context(eng)
            with pytest.raises(AlchemistError):
                ac.configure(weight=0)
            with pytest.raises(AlchemistError):
                ac.configure(weight=-2.0)
            with pytest.raises(AlchemistError):
                ac.configure(quotas={"max_queue_depth": -1})
            with pytest.raises(AlchemistError):
                ac.configure(quotas={"bogus_knob": 3})
            ac.stop()
        finally:
            eng.shutdown()

    def test_disconnect_reclaims_qos_state(self):
        eng = _qos_engine(qos_quotas={"max_inflight_bytes": 10 ** 6})
        try:
            ac = _context(eng)
            sid = ac.session
            ac.configure(weight=5.0)
            eng.admission.reserve_upload(sid, 500)
            ac.stop()
            assert eng.admission.inflight_bytes(sid) == 0
            assert eng.admission.quota_for(sid) == eng.admission.defaults
        finally:
            eng.shutdown()


# ---------------------------------------------------------------------------
# default-off identity
# ---------------------------------------------------------------------------
class TestQosDisabled:
    def test_defaults_off_and_fifo_policy(self):
        eng = AlchemistEngine(make_engine_mesh(2))
        try:
            assert eng.qos_enabled is False
            assert eng.admission is None
            assert isinstance(eng.scheduler._ready, FifoReadyQueue)
            stats = eng.qos_stats()
            assert stats["enabled"] is False
            assert stats["admitted"] == 0 and stats["rejected"] == 0
        finally:
            eng.shutdown()

    def test_quotas_without_qos_is_a_constructor_error(self):
        with pytest.raises(ValueError):
            AlchemistEngine(make_engine_mesh(2),
                            qos_quotas={"max_queue_depth": 4})

    def test_configure_weight_rejected_when_disabled(self):
        eng = AlchemistEngine(make_engine_mesh(2))
        try:
            ac = _context(eng)
            with pytest.raises(AlchemistError):
                ac.configure(weight=2.0)
            with pytest.raises(AlchemistError):
                ac.configure(quotas={"max_queue_depth": 4})
            # and the default-off configure echo carries no QoS keys
            eff = ac.configure(fusion=True)
            assert "weight" not in eff and "quotas" not in eff
            ac.stop()
        finally:
            eng.shutdown()


# ---------------------------------------------------------------------------
# warmup surface (satellite: explicit no-op on eager backends)
# ---------------------------------------------------------------------------
class TestWarmupSurface:
    def test_reference_backend_warmup_is_explicit_noop(self):
        eng = AlchemistEngine(make_engine_mesh(2))
        try:
            stats = eng.warmup(backend="reference")
            assert stats["skipped"] is True
            assert "no AOT compile surface" in stats["reason"]
            assert stats["compiled"] == 0 and stats["replayed"] == 0
            assert eng.compile_log.stats()["warmup_compiles"] == 0
        finally:
            eng.shutdown()

    def test_unknown_backend_warmup_reports_why(self):
        eng = AlchemistEngine(make_engine_mesh(2))
        try:
            stats = eng.warmup(backend="not-a-backend")
            assert stats["skipped"] is True
            assert "not registered" in stats["reason"]
        finally:
            eng.shutdown()

    def test_jax_backend_warmup_compiles(self):
        eng = AlchemistEngine(make_engine_mesh(2))
        try:
            stats = eng.warmup(backend="jax", grid=(32,))
            assert stats["skipped"] is False and stats["reason"] == ""
            assert stats["compiled"] + stats["cached"] > 0
        finally:
            eng.shutdown()

    def test_compile_stats_reports_active_backend(self):
        eng = AlchemistEngine(make_engine_mesh(2))
        try:
            assert eng.compile_stats()["active_backend"] == \
                eng.default_backend
        finally:
            eng.shutdown()
