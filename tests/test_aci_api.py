"""ACI surface tests: typed catalog + describe round-trip, library
façades, fail-fast client-side validation, the unified lazy AlMatrix
(zero-round-trip chaining, operator sugar, failure propagation), the
double-free guard, and the context-manager lifecycle."""
import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine, AlMatrix
from repro.core import protocol
from repro.core.context import AlchemistError
from repro.core.engine import ENGINE_LIBRARY, make_engine_mesh
from repro.core.handles import MatrixHandle
from repro.core.libraries import elemental, mllib, skylark
from repro.core.libraries import spec as specs

RNG = np.random.RandomState(0)


@pytest.fixture()
def engine():
    # cache off: several tests count submits / force recomputation
    eng = AlchemistEngine(make_engine_mesh(1), cache_entries=0)
    yield eng
    eng.shutdown()


@pytest.fixture()
def ac(engine):
    ctx = AlchemistContext(engine=engine)
    ctx.register_library("elemental", elemental)
    ctx.register_library("skylark", skylark)
    ctx.register_library("mllib", mllib)
    return ctx


def crossings(engine) -> int:
    """Client->engine protocol crossings so far (wire endpoints only;
    transfers are counted separately via the transfer log)."""
    return sum(engine.endpoint_counts.values())


# ---- spec layer -----------------------------------------------------------
def test_routine_decorator_declares_schema():
    sp = elemental.qr.spec
    assert sp.outputs == ("Q", "R")
    assert [p.name for p in sp.params] == ["A"]
    assert sp.params[0].kind == specs.MATRIX
    assert sp.declared
    sv = elemental.truncated_svd.spec
    assert sv.outputs == ("U", "S", "V")
    k = sv.param("k")
    assert k.kind == "int" and k.required
    over = sv.param("oversample")
    assert not over.required and over.default == 32


def test_spec_bind_rejects_bad_calls():
    sp = elemental.qr.spec
    with pytest.raises(specs.SpecError, match="missing required"):
        sp.bind((), {})
    with pytest.raises(specs.SpecError, match="unexpected keyword"):
        sp.bind((), {"A": 1, "k": 2})
    with pytest.raises(specs.SpecError, match="multiple values"):
        sp.bind((1,), {"A": 2})
    with pytest.raises(specs.SpecError, match="at most"):
        sp.bind((1, 2), {})


def test_spec_wire_roundtrip_preserves_everything():
    for fn in (elemental.qr, elemental.truncated_svd, skylark.cg_solve):
        sp = fn.spec
        assert specs.from_wire(specs.to_wire(sp)) == sp


def test_undecorated_routine_catalogs_by_introspection():
    def mystery(engine, A, k: int = 3):
        return {}

    sp = specs.spec_of(mystery)
    assert not sp.declared and sp.outputs == ()
    assert sp.param("A").kind == specs.MATRIX
    assert sp.param("k").default == 3


# ---- describe endpoint ----------------------------------------------------
def test_describe_roundtrips_all_bundled_libraries(ac):
    cats = ac._describe()
    for lib, module in (("elemental", elemental), ("skylark", skylark),
                        ("mllib", mllib)):
        assert lib in cats
        wire = cats[lib]["routines"]
        assert set(wire) == set(module.ROUTINES)
        for rn, fn in module.ROUTINES.items():
            assert specs.from_wire(wire[rn]) == specs.spec_of(fn, rn)
    # engine builtins are discoverable too
    assert "load_library" in cats[ENGINE_LIBRARY]["routines"]


def test_describe_single_library_and_unknown(ac):
    cats = ac._describe("skylark")
    assert set(cats) == {"skylark"}
    assert "cg_solve" in cats["skylark"]["routines"]
    with pytest.raises(AlchemistError, match="not registered.*elemental"):
        ac.library("nope")


def test_describe_requires_known_session(engine):
    res = protocol.decode_result(engine.describe(
        protocol.encode_describe(protocol.Describe(session=999))))
    assert "session #999" in res.error
    # same wire discipline as submit: the system session is not a client
    res0 = protocol.decode_result(engine.describe(
        protocol.encode_describe(protocol.Describe(session=0))))
    assert "system session" in res0.error


def test_libraries_lists_loaded(ac):
    libs = ac.libraries()
    assert {"elemental", "skylark", "mllib", ENGINE_LIBRARY} <= set(libs)


# ---- library façade -------------------------------------------------------
def test_facade_qr_tuple_unpacks_in_declared_order(ac):
    a = RNG.randn(96, 24).astype(np.float32)
    A = ac.send_matrix(a)
    Q, R = ac.library("elemental").qr(A)
    assert Q.is_deferred and R.is_deferred
    q, r = Q.to_numpy(), R.to_numpy()
    assert q.shape == (96, 24) and r.shape == (24, 24)
    np.testing.assert_allclose(q @ r, a, atol=1e-4)


def test_facade_single_output_returns_one_proxy(ac):
    G = ac.library("elemental").gram(ac.send_matrix(RNG.randn(32, 8)))
    assert isinstance(G, AlMatrix)
    assert G.shape == (8, 8)


def test_facade_positional_args_bind_by_declared_order(ac):
    a = ac.send_matrix(RNG.randn(16, 4).astype(np.float32))
    Q, R = ac.library("elemental").qr(a)      # positional A
    assert R.shape == (4, 4)


def test_facade_scalar_outputs_via_stats(ac):
    A = ac.send_matrix(RNG.randn(64, 16).astype(np.float32))
    U, S, V = ac.library("elemental").truncated_svd(A, k=4)
    st = S.stats()
    assert st["lanczos_iters"] >= 4 and st["matvecs"] >= 4
    assert "_exec_s" in st
    assert not any(isinstance(v, MatrixHandle) for v in st.values())


def test_facade_unknown_routine_lists_catalog(ac):
    el = ac.library("elemental")
    with pytest.raises(AttributeError, match="no routine 'svd'.*catalog:"):
        el.svd
    assert "qr" in dir(el)


def test_facade_unknown_kwarg_fails_pre_submit(ac, engine):
    el = ac.library("elemental")
    before = crossings(engine)
    with pytest.raises(specs.SpecError, match="unexpected keyword.*rank"):
        el.truncated_svd(A=ac.send_matrix(RNG.randn(8, 4)), rank=2)
    with pytest.raises(specs.SpecError, match="missing required"):
        el.multiply(A=ac.send_matrix(RNG.randn(4, 4)))
    with pytest.raises(specs.SpecError, match="expects int"):
        el.random_matrix(rows=8, cols=4, seed=1.5)
    with pytest.raises(specs.SpecError, match="engine-resident matrix"):
        el.qr(A=np.zeros((3, 3)))
    assert crossings(engine) == before      # nothing crossed the bridge


def test_facade_cross_session_proxy_rejected_client_side(ac, engine):
    other = AlchemistContext(engine=engine, client_name="other")
    foreign = other.send_matrix(RNG.randn(8, 4))
    el = ac.library("elemental")              # catalog fetched up front
    before = crossings(engine)
    with pytest.raises(AlchemistError, match="session-scoped"):
        el.qr(A=foreign)
    assert crossings(engine) == before
    other.stop()


def test_facade_mllib_baseline_runs_through_catalog(ac):
    x = RNG.randn(60, 6).astype(np.float32)
    y = RNG.randn(60, 2).astype(np.float32)
    W = ac.library("mllib").cg_solve(
        X=ac.send_matrix(x), Y=ac.send_matrix(y), lam=1e-3,
        max_iters=300, tol=1e-10)
    want = np.linalg.solve(x.T @ x + 60 * 1e-3 * np.eye(6), x.T @ y)
    np.testing.assert_allclose(W.to_numpy(), want, atol=1e-4)
    assert W.stats()["bsp_rounds"] >= 1


# ---- lazy chaining / zero intermediate round trips ------------------------
def test_deferred_chain_submits_with_zero_intermediate_round_trips(
        ac, engine):
    el = ac.library("elemental")
    A = ac.send_matrix(RNG.randn(24, 24).astype(np.float32))
    fetches_before = len(engine.transfer_log.records)
    before = dict(engine.endpoint_counts)
    x = A
    stages = 5
    for _ in range(stages):
        x = el.multiply(A=x, B=A)
    after = dict(engine.endpoint_counts)
    # exactly one submit per stage; no polls, waits, or fetches crossed
    assert after["submit"] - before.get("submit", 0) == stages
    assert after.get("task_op", 0) == before.get("task_op", 0)
    assert len(engine.transfer_log.records) == fetches_before
    # forcing costs exactly one wait
    x.result()
    assert engine.endpoint_counts["task_op"] == before.get("task_op", 0) + 1
    want = np.linalg.matrix_power(np.asarray(A.to_numpy()), stages + 1)
    np.testing.assert_allclose(x.to_numpy(), want, rtol=2e-2)


def test_operator_sugar_matches_numpy(ac):
    a = RNG.randn(12, 6).astype(np.float32)
    b = RNG.randn(6, 9).astype(np.float32)
    A, B = ac.send_matrix(a), ac.send_matrix(b)
    np.testing.assert_allclose((A @ B).to_numpy(), a @ b, atol=1e-5)
    np.testing.assert_allclose(A.T.to_numpy(), a.T, atol=1e-6)
    np.testing.assert_allclose((A + A).to_numpy(), a + a, atol=1e-6)
    # mixed deferred/concrete chain: (A @ B).T @ (A @ B)
    AB = A @ B
    np.testing.assert_allclose((AB.T @ AB).to_numpy(),
                               (a @ b).T @ (a @ b), atol=1e-3)


def test_operator_matmul_accepts_1d_vector_operand(ac):
    v = ac.send_matrix(RNG.randn(6).astype(np.float32))
    M = ac.send_matrix(RNG.randn(6, 3).astype(np.float32))
    np.testing.assert_allclose((v @ M).to_numpy(),
                               v.to_numpy() @ M.to_numpy(), atol=1e-5)


def test_operator_shape_mismatch_fails_client_side(ac, engine):
    A = ac.send_matrix(RNG.randn(4, 3))
    B = ac.send_matrix(RNG.randn(4, 3))
    C = ac.send_matrix(RNG.randn(2, 2))
    before = crossings(engine)
    with pytest.raises(AlchemistError, match="shape mismatch for @"):
        A @ B
    with pytest.raises(AlchemistError, match="shape mismatch for \\+"):
        A + C
    assert crossings(engine) == before
    # raw arrays never silently coerce, in either operand position
    with pytest.raises(TypeError):
        A @ np.zeros((3, 3))
    with pytest.raises(TypeError):
        np.zeros((5, 4)) @ A


def test_chaining_on_known_failed_producer_raises_immediately(ac):
    el = ac.library("elemental")
    ghost = AlMatrix.wrap(ac, MatrixHandle.fresh((3, 3), "float32"))
    bad = el.gram(A=ghost)                    # submits; fails engine-side
    with pytest.raises(AlchemistError):
        bad.result()
    # the failure is now known client-side: chaining fails fast, pre-submit
    with pytest.raises(AlchemistError, match="producer failed"):
        el.qr(A=bad)


def test_chaining_on_unfetched_failed_producer_fails_at_force(ac):
    el = ac.library("elemental")
    ghost = AlMatrix.wrap(ac, MatrixHandle.fresh((3, 3), "float32"))
    bad = el.gram(A=ghost)
    # chain before anyone observed the failure: the data edge carries it
    downstream = el.qr(A=bad)
    with pytest.raises(AlchemistError, match="upstream|KeyError"):
        downstream[0].result()


def test_legacy_call_accepts_deferred_almatrix(ac):
    el = ac.library("elemental")
    A = ac.send_matrix(RNG.randn(16, 8).astype(np.float32))
    G = el.gram(A)                            # deferred proxy
    res = ac.call("elemental", "qr", A=G)     # old API, new proxy
    assert res["R"].shape == (8, 8)


# ---- AlMatrix lifecycle ---------------------------------------------------
def test_wrap_and_legacy_constructor_shim(ac):
    a = RNG.randn(8, 4)
    legacy_data = AlMatrix(ac, a)             # old dual-mode: upload
    assert legacy_data.shape == (8, 4)
    h = legacy_data.handle
    legacy_handle = AlMatrix(ac, h)           # old dual-mode: wrap
    assert legacy_handle.handle is h
    assert AlMatrix.wrap(ac, h).handle is h
    assert AlMatrix.from_handle(ac, h).handle is h


def test_double_free_guarded(ac, engine):
    al = ac.send_matrix(RNG.randn(16, 16))
    h = al.handle
    engine.retain(h)                          # someone else's reference
    al.free()
    assert engine.refcount(h) == 1            # theirs survives
    with pytest.raises(AlchemistError, match="double free"):
        al.free()
    assert engine.refcount(h) == 1            # ...still survives
    with pytest.raises(AlchemistError, match="was freed"):
        al.to_numpy()


def test_freed_proxy_rejected_as_argument(ac):
    al = ac.send_matrix(RNG.randn(8, 4))
    al.free()
    with pytest.raises(AlchemistError, match="was freed"):
        ac.library("elemental").qr(A=al)


# ---- context manager & stop semantics -------------------------------------
def test_context_manager_stops_on_exit(engine):
    engine.load_library("elemental", elemental)
    with AlchemistContext(engine=engine) as ac:
        al = ac.send_matrix(RNG.randn(8, 8))
        assert engine.resident_bytes() > 0
        session = ac.session
    assert ac._stopped
    assert engine.resident_bytes() == 0       # reclaimed at disconnect
    with pytest.raises(AlchemistError):
        ac.call("elemental", "qr", A=al)
    assert all(s.id != session for s in engine.sessions())


def test_facade_call_on_stopped_context_fails_client_side(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    el = ac.library("elemental")
    A = ac.send_matrix(RNG.randn(4, 4))
    ac.stop()
    with pytest.raises(AlchemistError, match="stopped"):
        el.qr(A=A)              # same fail-fast as the legacy shim


def test_context_manager_stops_on_error(engine):
    with pytest.raises(ValueError):
        with AlchemistContext(engine=engine) as ac:
            raise ValueError("boom")
    assert ac._stopped


def test_post_stop_future_use_raises_clear_error(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    fetched = ac.call_async("elemental", "random_matrix", rows=4, cols=4)
    fetched.result()                          # fetched before stop: kept
    orphan = ac.call_async("elemental", "random_matrix", rows=4, cols=4,
                           seed=7)
    U = AlMatrix.deferred(ac, orphan, "A")
    ac.stop()
    assert fetched.result()["A"].shape == (4, 4)   # client-side cache
    for use in (orphan.result, orphan.state, orphan.done,
                lambda: orphan["A"], U.result, lambda: U.shape):
        with pytest.raises(AlchemistError, match="stopped before task"):
            use()


def test_post_stop_deferred_chain_arg_raises_clear_error(engine):
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    ac2 = AlchemistContext(engine=engine)
    orphan = AlMatrix.deferred(
        ac, ac.call_async("elemental", "random_matrix", rows=4, cols=4),
        "A")
    ac.stop()
    with pytest.raises(AlchemistError, match="stopped before task"):
        orphan._wire_arg()
    ac2.stop()
