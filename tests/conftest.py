import os
import sys

import pytest

# src-layout import path (tests run with PYTHONPATH=src, but be robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---- bridge parametrization -------------------------------------------
# The protocol suites run twice: once over the in-memory bridge (the
# engine object itself) and once over real TCP (core/server.py +
# core/wire.py SocketBridge) — same test bodies, byte-identical protocol
# traffic, so every session/scheduler/cache/ACI behavior is proven on the
# transport the paper actually uses. Suites outside this list are
# bridge-agnostic (they poke engine internals directly) and run once.
_BRIDGED_SUITES = {
    "test_sessions_streaming",
    "test_scheduler_async",
    "test_cache",
    "test_aci_api",
    "test_qos",
}


def pytest_generate_tests(metafunc):
    if metafunc.module.__name__ in _BRIDGED_SUITES:
        metafunc.parametrize("bridge_mode", ["inmemory", "socket"],
                             indirect=True)


# ---- lifecycle state-machine monitoring -------------------------------
# The suites that exercise teardown races and QoS backpressure run with
# the repro.analysis.statemachine runtime monitor armed: every engine/
# scheduler/server constructed inside them records lifecycle transitions,
# and the test fails if any illegal edge, orphan, remint, or dead-scope
# activity was observed — on both bridges (the socket variant drives the
# real server's upload machine too).
_STM_MONITORED_SUITES = {
    "test_server_faults",
    "test_qos",
}


@pytest.fixture(autouse=True)
def stm_monitor(request, monkeypatch):
    if request.module.__name__ not in _STM_MONITORED_SUITES:
        yield
        return
    from repro.analysis import statemachine
    monkeypatch.setenv(statemachine.ENV_FLAG, "1")
    statemachine.TRACE.reset()
    yield
    statemachine.TRACE.assert_clean()
    statemachine.TRACE.reset()


@pytest.fixture(autouse=True)
def bridge_mode(request, monkeypatch):
    """``inmemory`` leaves everything untouched. ``socket`` reroutes
    every ``AlchemistContext(engine=...)`` construction through a real
    TCP server wrapped around *the same engine object*: the context
    talks frames over localhost while the test keeps direct in-process
    access to the engine for its assertions. One server per distinct
    engine, started lazily, stopped at test teardown."""
    mode = getattr(request, "param", "inmemory")
    if mode != "socket":
        yield mode
        return

    from repro.core import wire
    from repro.core.context import AlchemistContext
    from repro.core.engine import AlchemistEngine, make_engine_mesh
    from repro.core.server import AlchemistServer

    servers = {}                       # id(engine) -> AlchemistServer
    real_init = AlchemistContext.__init__

    def socket_init(self, num_workers=None, engine=None, **kw):
        if kw.get("address") is not None \
                or isinstance(engine, wire.SocketBridge):
            return real_init(self, num_workers=num_workers,
                             engine=engine, **kw)
        if engine is None:
            engine = AlchemistEngine(make_engine_mesh(num_workers))
        srv = servers.get(id(engine))
        if srv is None:
            srv = AlchemistServer(engine=engine).start()
            servers[id(engine)] = srv
        return real_init(self, address=srv.address, **kw)

    monkeypatch.setattr(AlchemistContext, "__init__", socket_init)
    yield mode
    for srv in servers.values():
        srv.stop()