"""Per-architecture smoke tests (deliverable f): each assigned arch, as a
REDUCED variant of the same family, runs one forward/train step and a
prefill+decode round on CPU; asserts output shapes and no NaNs, and that
decode-with-cache agrees with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ShapeConfig
from repro.configs import ALL_ARCHS, get_reduced
from repro.models import io as mio
from repro.models.model import build_model
from repro.nn.core import init_params
from repro.train.loop import make_train_step
from repro.train.optim import adamw_init
from repro.common.config import TrainConfig

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, mode="train")


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ALL_ARCHS:
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(built, arch):
    cfg, model, params = built[arch]
    batch = mio.make_batch(cfg, SHAPE)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, TrainConfig(total_steps=10)))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) < 2 * np.log(cfg.vocab_size)
    assert int(new_opt["step"]) == 1
    # params actually changed
    a0 = jax.tree.leaves(params)[0]
    a1 = jax.tree.leaves(new_params)[0]
    assert not np.allclose(np.asarray(a0), np.asarray(a1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(built, arch):
    """Greedy decode from a prefilled cache must match slicing the full
    forward logits (teacher forcing) at the same position."""
    cfg, model, params = built[arch]
    batch = mio.make_batch(cfg, SHAPE)
    pf = {k: v for k, v in batch.items() if k != "labels"}

    # full forward logits at final position
    x, _, _ = model.forward(
        params, pf["tokens"],
        **({"patch_embeds": pf["patch_embeds"]} if "patch_embeds" in pf else {}),
        **({"frames": pf["frames"]} if "frames" in pf else {}))
    full_last = model._unembed(params, x[:, -1:])[:, 0]

    logits, state = model.prefill(params, pf, seq_len=SHAPE.seq_len + 8)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full_last, np.float32),
                               rtol=3e-2, atol=3e-2)

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    logits2, state2 = model.decode_step(params, state, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())
    assert int(state2.index) == int(state.index) + 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_loss_decreases_under_training(built, arch):
    """A few steps on repeated data must reduce loss (end-to-end gradient
    flow through every block type)."""
    cfg, model, params = built[arch]
    batch = mio.make_batch(cfg, SHAPE)
    opt = adamw_init(params)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, tc))
    losses = []
    for _ in range(6):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
