"""Content-addressed cache semantics: hit/miss keys, invalidation on
overwrite and forced free, cross-session isolation (cached results are
aliased, never leaked, across namespaces), dedup-upload aliasing with
zero-byte crossings, interaction with LRU spill and the cache's own LRU,
and cache lookups racing the scheduler's hazard edges."""
import threading
import time

import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine, protocol
from repro.core.context import AlchemistError
from repro.core.engine import make_engine_mesh
from repro.core.libraries import elemental, skylark

RNG = np.random.RandomState(7)


@pytest.fixture()
def engine():
    eng = AlchemistEngine(make_engine_mesh(1), scheduler_workers=4)
    eng.load_library("elemental", elemental)
    eng.load_library("skylark", skylark)
    return eng


@pytest.fixture()
def ac(engine):
    return AlchemistContext(engine=engine)


# =====================================================================
# hit/miss keys
# =====================================================================
def test_identical_call_hits_and_returns_same_handles(ac, engine):
    al = ac.send_matrix(RNG.randn(64, 16).astype(np.float32))
    r1 = ac.call("elemental", "gram", A=al)
    r2 = ac.call("elemental", "gram", A=al)
    assert not r1["_cache_hit"] and r2["_cache_hit"]
    assert r2["G"].id == r1["G"].id          # same session: same handles
    assert r2["_saved_s"] > 0
    log = engine.cache_log.session_summary(ac.session)
    assert log["hits"] == 1 and log["misses"] == 1
    np.testing.assert_allclose(ac.wrap(r2["G"]).to_numpy(),
                               ac.wrap(r1["G"]).to_numpy())


def test_param_change_misses(ac):
    al = ac.send_matrix(RNG.randn(64, 16).astype(np.float32))
    r1 = ac.call("elemental", "truncated_svd", A=al, k=4)
    r2 = ac.call("elemental", "truncated_svd", A=al, k=5)
    assert not r1["_cache_hit"] and not r2["_cache_hit"]
    r3 = ac.call("elemental", "truncated_svd", A=al, k=4)
    assert r3["_cache_hit"]


def test_different_content_misses(ac):
    a = ac.send_matrix(RNG.randn(32, 8).astype(np.float32))
    b = ac.send_matrix(RNG.randn(32, 8).astype(np.float32))
    assert not ac.call("elemental", "gram", A=a)["_cache_hit"]
    assert not ac.call("elemental", "gram", A=b)["_cache_hit"]


def test_same_content_different_handles_hit(ac):
    """Content addressing, not handle addressing: two uploads of equal
    bytes (the second is a dedup alias) share one cache key."""
    x = RNG.randn(48, 12).astype(np.float32)
    a = ac.send_matrix(x)
    b = ac.send_matrix(x)                    # dedup alias, different id
    assert b.handle.id != a.handle.id
    assert not ac.call("elemental", "gram", A=a)["_cache_hit"]
    assert ac.call("elemental", "gram", A=b)["_cache_hit"]


def test_creation_routines_are_not_memoized(ac, engine):
    """Commands with no handle args (random_matrix, test shims) are not
    cached: every call runs."""
    r1 = ac.call("elemental", "random_matrix", rows=16, cols=4, seed=3)
    r2 = ac.call("elemental", "random_matrix", rows=16, cols=4, seed=3)
    assert not r1["_cache_hit"] and not r2["_cache_hit"]
    assert r2["A"].id != r1["A"].id


def test_write_routines_are_not_memoized(engine, ac):
    def scale(eng, A, factor=2.0):
        eng.overwrite(A, eng.get(A) * factor)
        return {"A": A}
    scale.writes = ("A",)

    class _Lib:
        ROUTINES = {"scale": scale}

    engine.load_library("w", _Lib)
    al = ac.send_matrix(np.ones((8, 2), np.float32))
    ac.call("w", "scale", A=al, factor=3.0)
    ac.call("w", "scale", A=al, factor=3.0)  # must run again
    np.testing.assert_allclose(np.asarray(engine.get(al.handle)),
                               9.0 * np.ones((8, 2), np.float32))


# =====================================================================
# DONE-on-submit fast path
# =====================================================================
def test_fast_path_mints_no_task(ac, engine):
    al = ac.send_matrix(RNG.randn(32, 8).astype(np.float32))
    ac.call("elemental", "qr", A=al)
    tasks_before = len(engine.task_log.records)
    fut = ac.call_async("elemental", "qr", A=al)
    assert fut.done() and fut.state() == "DONE"
    out = fut.result()
    assert out["_cache_hit"] and fut.task == 0
    assert len(engine.task_log.records) == tasks_before  # no task ran
    # outputs resolve to real handles immediately
    assert out["Q"].shape == (32, 8)


def test_hit_survives_engine_restartless_wire_roundtrip(ac, engine):
    """The wire Result of a fast-path hit carries cache_hit/saved_s."""
    al = ac.send_matrix(RNG.randn(16, 4).astype(np.float32))
    ac.call("elemental", "gram", A=al)
    wire = protocol.encode_command(protocol.Command(
        "elemental", "gram", {"A": al.handle}, session=ac.session))
    res = protocol.decode_result(engine.run(wire))
    assert res.cache_hit and res.saved_s > 0 and res.state == "DONE"
    assert res.task == 0 and not res.error


# =====================================================================
# invalidation: overwrite / free
# =====================================================================
def test_overwrite_of_input_invalidates(ac, engine):
    x = np.ones((8, 4), np.float32)
    al = ac.send_matrix(x)
    r1 = ac.call("elemental", "gram", A=al)
    engine.overwrite(al.handle, 2 * np.ones((8, 4), np.float32))
    r2 = ac.call("elemental", "gram", A=al)
    assert not r2["_cache_hit"]
    np.testing.assert_allclose(ac.wrap(r2["G"]).to_numpy(),
                               4.0 * (x.T @ x), rtol=1e-5)
    assert r1["G"].id != r2["G"].id


def test_overwrite_of_output_invalidates(ac, engine):
    al = ac.send_matrix(RNG.randn(8, 4).astype(np.float32))
    r1 = ac.call("elemental", "gram", A=al)
    engine.overwrite(r1["G"], np.zeros((4, 4), np.float32))
    r2 = ac.call("elemental", "gram", A=al)
    assert not r2["_cache_hit"]              # entry died with its output
    assert engine.cache_log.summary()["invalidations"] >= 1


def test_client_free_does_not_invalidate(ac, engine):
    """The cache retains its outputs: a client free drops the client's
    reference but the memoized result keeps serving."""
    al = ac.send_matrix(RNG.randn(16, 4).astype(np.float32))
    r1 = ac.call("elemental", "gram", A=al)
    ac.free(r1["G"])                         # client lets go
    r2 = ac.call("elemental", "gram", A=al)
    assert r2["_cache_hit"]
    # content still correct after the free
    np.testing.assert_allclose(
        ac.wrap(r2["G"]).to_numpy(),
        np.asarray(engine.get(al.handle)).T
        @ np.asarray(engine.get(al.handle)), rtol=1e-4, atol=1e-4)


def test_forced_reclaim_invalidates(ac, engine):
    al = ac.send_matrix(RNG.randn(16, 4).astype(np.float32))
    r1 = ac.call("elemental", "gram", A=al)
    # trusted path frees both references (client's + cache's): reclaimed
    engine.free(r1["G"])
    engine.free(r1["G"])
    r2 = ac.call("elemental", "gram", A=al)
    assert not r2["_cache_hit"]


def test_lru_spill_does_not_invalidate():
    """A spilled cached output transparently reloads on a hit."""
    nbytes = 64 * 16 * 4
    engine = AlchemistEngine(make_engine_mesh(1),
                             memory_budget_bytes=2 * nbytes)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(RNG.randn(64, 16).astype(np.float32))
    r1 = ac.call("elemental", "gram", A=al)
    # push the cached output out of device memory
    for i in range(3):
        ac.send_matrix(RNG.randn(64, 16).astype(np.float32))
    assert engine.spilled_bytes() > 0
    r2 = ac.call("elemental", "gram", A=al)
    assert r2["_cache_hit"] and r2["G"].id == r1["G"].id
    assert ac.wrap(r2["G"]).to_numpy().shape == (16, 16)


def test_cache_lru_eviction_releases_refs():
    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=2)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    als = [ac.send_matrix(RNG.randn(16, 4).astype(np.float32))
           for _ in range(3)]
    outs = [ac.call("elemental", "gram", A=al) for al in als]
    # third store evicted the first entry; its retained ref was released
    assert engine.refcount(outs[0]["G"]) == 1       # client's ref only
    assert engine.refcount(outs[2]["G"]) == 2       # client + cache
    assert not ac.call("elemental", "gram", A=als[0])["_cache_hit"]
    assert ac.call("elemental", "gram", A=als[2])["_cache_hit"]


# =====================================================================
# cross-session isolation
# =====================================================================
def test_cross_session_hit_aliases_not_leaks(engine):
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    x = RNG.randn(32, 8).astype(np.float32)
    ra = a.call("elemental", "qr", A=a.send_matrix(x))
    rb = b.call("elemental", "qr", A=b.send_matrix(x))
    assert rb["_cache_hit"]
    # B got fresh handle IDs in ITS namespace, not A's handles
    assert rb["Q"].id != ra["Q"].id and rb["R"].id != ra["R"].id
    assert rb["Q"].id in engine.session(b.session).owned
    assert rb["Q"].id not in engine.session(a.session).owned
    np.testing.assert_allclose(b.wrap(rb["Q"]).to_numpy(),
                               a.wrap(ra["Q"]).to_numpy())
    # A cannot resolve B's alias and vice versa
    with pytest.raises(AlchemistError):
        a.call("elemental", "gram", A=rb["Q"])
    with pytest.raises(AlchemistError):
        b.call("elemental", "gram", A=ra["Q"])


def test_producer_disconnect_keeps_consumer_aliases_alive(engine):
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    x = RNG.randn(16, 4).astype(np.float32)
    a.call("elemental", "gram", A=a.send_matrix(x))
    rb = b.call("elemental", "gram", A=b.send_matrix(x))
    assert rb["_cache_hit"]
    a.stop()                       # producer leaves; B's aliases survive
    np.testing.assert_allclose(b.wrap(rb["G"]).to_numpy(), x.T @ x,
                               rtol=1e-4, atol=1e-4)
    b.stop()
    assert engine.resident_bytes() == 0


def test_disconnect_invalidates_the_sessions_cached_results(engine):
    a = AlchemistContext(engine=engine)
    x = RNG.randn(16, 4).astype(np.float32)
    a.call("elemental", "gram", A=a.send_matrix(x))
    a.stop()
    # a later tenant with the same content recomputes (no dangling entry)
    b = AlchemistContext(engine=engine)
    rb = b.call("elemental", "gram", A=b.send_matrix(x))
    assert not rb["_cache_hit"]
    np.testing.assert_allclose(b.wrap(rb["G"]).to_numpy(), x.T @ x,
                               rtol=1e-4, atol=1e-4)


# =====================================================================
# transfer dedup
# =====================================================================
def test_dedup_upload_zero_modeled_bytes(ac, engine):
    x = RNG.randn(128, 32).astype(np.float32)
    a1 = ac.send_matrix(x)
    recs_before = len(engine.transfer_log.records)
    a2 = ac.send_matrix(x)
    rec = a2.last_transfer
    assert rec.dedup and rec.nbytes == 0 and rec.modeled_socket_s == 0.0
    assert rec.logical_nbytes == x.nbytes
    # the dedup crossing is logged distinctly, as a single record
    assert len(engine.transfer_log.records) == recs_before + 1
    assert engine.transfer_log.records[-1].dedup
    summ = engine.transfer_log.session_summary(ac.session)
    assert summ["dedup_uploads"] == 1
    assert summ["dedup_bytes_saved"] == x.nbytes
    # alias resolves to identical content under a distinct handle
    assert a2.handle.id != a1.handle.id
    np.testing.assert_array_equal(a2.to_numpy(), x)


def test_dedup_respects_free(ac, engine):
    x = RNG.randn(64, 8).astype(np.float32)
    a1 = ac.send_matrix(x)
    a1.free()                        # store reclaimed -> index dropped
    a2 = ac.send_matrix(x)
    assert not a2.last_transfer.dedup      # full stream again
    assert a2.last_transfer.nbytes == x.nbytes


def test_dedup_distinguishes_dtype_and_shape(ac):
    x = RNG.randn(32, 8).astype(np.float32)
    ac.send_matrix(x)
    assert not ac.send_matrix(x.astype(np.float64)).last_transfer.dedup
    assert not ac.send_matrix(x.reshape(8, 32)).last_transfer.dedup


def test_dedup_opt_out_streams(ac):
    x = RNG.randn(32, 8).astype(np.float32)
    ac.send_matrix(x)
    rec = ac.send_matrix(x, dedup=False).last_transfer
    assert not rec.dedup and rec.nbytes == x.nbytes


def test_dedup_aliases_are_copy_on_write(ac, engine):
    """Overwriting through one alias must not change the other's view."""
    x = np.ones((8, 4), np.float32)
    a1 = ac.send_matrix(x)
    a2 = ac.send_matrix(x)
    assert a2.last_transfer.dedup
    engine.overwrite(a2.handle, 5 * np.ones((8, 4), np.float32))
    np.testing.assert_array_equal(a1.to_numpy(), x)
    np.testing.assert_array_equal(a2.to_numpy(), 5 * x)


def test_rowmatrix_upload_dedups_against_array_upload(ac):
    """Content addressing is layout-independent client-side: the same
    bytes uploaded as ndarray then as a RowMatrix alias each other."""
    from repro.frontend.rowmatrix import RowMatrix
    x = RNG.randn(60, 6)
    ac.send_matrix(x)
    rm = RowMatrix.from_array(x, num_partitions=4)
    assert ac.send_matrix(rm).last_transfer.dedup


def test_dedup_is_chunk_boundary_invariant(ac):
    """The fingerprint digests row-major bytes, not the chunk plan: the
    same matrix re-sent with a different chunk_rows still aliases."""
    x = RNG.randn(100, 8).astype(np.float32)
    ac.send_matrix(x, chunk_rows=33)
    assert ac.send_matrix(x, chunk_rows=7).last_transfer.dedup
    assert ac.send_matrix(x).last_transfer.dedup


def test_uncached_rdd_source_is_consumed_exactly_once(ac):
    """An uncached RDD lineage (bare map_rows) must not be re-iterated by
    the dedup hash pass: partitions compute once, the fingerprint is
    taken inline from the streamed bytes, and equal content uploaded
    later still dedups against it."""
    from repro.frontend.rowmatrix import RowMatrix
    x = RNG.randn(40, 4)
    rm = RowMatrix.from_array(x, num_partitions=4)
    computes = []
    mapped = rm.map_rows(lambda p: computes.append(1) or (p * 2.0))
    assert not mapped.rdd.cached
    al = ac.send_matrix(mapped)
    # exactly one compute per partition: the width/dtype probe memoizes
    # the partition-0 realization it forced, and the stream reuses it
    assert len(computes) == 4
    assert not al.last_transfer.dedup        # no pre-stream lookup
    # the inline fingerprint matches what actually crossed: a cached
    # upload of the same bytes aliases against it
    assert ac.send_matrix(2.0 * x).last_transfer.dedup


def test_transfer_summary_does_not_count_dedup_as_chunk(ac, engine):
    x = RNG.randn(50, 4).astype(np.float32)
    ac.send_matrix(x, chunk_rows=10)         # 5 chunks
    ac.send_matrix(x, chunk_rows=10)         # dedup pseudo-record
    summ = engine.transfer_log.session_summary(ac.session)
    assert summ["to_engine_chunks"] == 5
    assert summ["dedup_uploads"] == 1


# =====================================================================
# cache lookups racing the scheduler's hazard edges
# =====================================================================
def test_hit_refused_while_writer_in_flight(engine):
    """Populate the cache, then submit a slow writer on the input and
    immediately a read of it: the read must NOT be served stale from the
    fast path — it queues behind the writer's hazard edge and recomputes
    on the new content."""
    def slow_scale(eng, A, factor=2.0, sleep=0.4):
        x = eng.get(A)
        time.sleep(sleep)
        eng.overwrite(A, x * factor)
        return {"A": A}
    slow_scale.writes = ("A",)

    def total(eng, A):
        return {"sum": float(np.asarray(eng.get(A)).sum())}

    class _Lib:
        ROUTINES = {"slow_scale": slow_scale, "total": total}

    engine.load_library("w", _Lib)
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(np.ones((8, 2), np.float32))
    assert ac.call("w", "total", A=al)["sum"] == 16.0       # populates
    assert ac.call("w", "total", A=al)["_cache_hit"]        # sanity: hits
    fw = ac.call_async("w", "slow_scale", A=al, factor=3.0)
    fr = ac.call_async("w", "total", A=al)
    # submitted while the writer is QUEUED/RUNNING: must not be DONE with
    # the stale sum
    out = fr.result()
    assert out["sum"] == 48.0 and not out["_cache_hit"]
    fw.result()


def test_concurrent_identical_calls_race_safely(engine):
    """Many threads, two sessions, same computation: every result is
    correct and complete whether it was computed, raced, or served."""
    ctxs = [AlchemistContext(engine=engine) for _ in range(4)]
    x = RNG.randn(96, 24).astype(np.float32)
    als = [c.send_matrix(x) for c in ctxs]
    outs: list[dict] = [None] * 8
    errors: list[Exception] = []

    def work(i):
        try:
            c, al = ctxs[i % 4], als[i % 4]
            outs[i] = c.call("elemental", "truncated_svd", A=al, k=4)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    want = np.linalg.svd(x, compute_uv=False)[:4]
    for i, out in enumerate(outs):
        c = ctxs[i % 4]
        s = c.wrap(out["S"]).to_numpy().ravel()
        np.testing.assert_allclose(s, want, rtol=1e-3)
    # at least one hit happened across the identical workloads
    assert engine.cache_log.summary()["hits"] >= 1
    for c in ctxs:
        c.stop()
    assert engine.resident_bytes() == 0


# =====================================================================
# observability
# =====================================================================
def test_cache_log_per_session_accounting(engine):
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    x = RNG.randn(32, 8).astype(np.float32)
    a.call("elemental", "gram", A=a.send_matrix(x))
    b.call("elemental", "gram", A=b.send_matrix(x))
    sa = engine.cache_log.session_summary(a.session)
    sb = engine.cache_log.session_summary(b.session)
    assert sa["misses"] == 1 and sa["hits"] == 0
    assert sb["hits"] == 1 and sb["misses"] == 0
    assert sb["dedup_uploads"] == 1 and sb["bytes_saved"] == x.nbytes
    assert sb["saved_s"] > 0 and sb["hit_rate"] == 1.0
    assert engine.cache_log.sessions() == sorted([a.session, b.session])


def test_library_reregistration_invalidates_its_entries(engine):
    """Cache keys hash the library NAME, not its code: re-registering a
    library under the same name must drop its memoized results — both on
    the in-process path and ahead of the fast path when the reload is a
    still-queued wire barrier."""
    def probe_v1(eng, A):
        return {"version": 1}

    def probe_v2(eng, A):
        return {"version": 2}

    class _V1:
        ROUTINES = {"probe": probe_v1}

    class _V2:
        ROUTINES = {"probe": probe_v2}

    engine.load_library("mylib", _V1)
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(RNG.randn(4, 2).astype(np.float32))
    assert ac.call("mylib", "probe", A=al)["version"] == 1
    assert ac.call("mylib", "probe", A=al)["_cache_hit"]     # memoized
    engine.load_library("mylib", _V2)
    out = ac.call("mylib", "probe", A=al)
    assert out["version"] == 2 and not out["_cache_hit"]
    # other libraries' entries survive a reload of mylib
    ac.call("elemental", "gram", A=al)
    engine.load_library("mylib", _V1)
    assert ac.call("elemental", "gram", A=al)["_cache_hit"]


def test_cache_disabled_engine_still_works():
    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=0)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(RNG.randn(16, 4).astype(np.float32))
    r1 = ac.call("elemental", "gram", A=al)
    r2 = ac.call("elemental", "gram", A=al)
    assert not r1["_cache_hit"] and not r2["_cache_hit"]
    assert r1["G"].id != r2["G"].id
