"""Tests for the multi-session streaming engine: the connect/disconnect
handshake, per-session handle namespaces, the chunked §3.2 transfer path,
and the handle lifecycle layer (refcounts, LRU spill, free_session)."""
import threading

import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine
from repro.core import protocol, transfer
from repro.core.context import AlchemistError
from repro.core.engine import SYSTEM_SESSION, make_engine_mesh
from repro.core.handles import MatrixHandle
from repro.core.libraries import elemental, skylark

RNG = np.random.RandomState(0)


@pytest.fixture()
def engine():
    return AlchemistEngine(make_engine_mesh(1))


# ---- protocol: session fields and error results round-trip ----
def test_handshake_roundtrip():
    hs = protocol.Handshake(action=protocol.CONNECT, client="spark-7")
    back = protocol.decode_handshake(protocol.encode_handshake(hs))
    assert back == hs
    bye = protocol.Handshake(action=protocol.DISCONNECT, session=42)
    assert protocol.decode_handshake(protocol.encode_handshake(bye)) == bye


def test_handshake_rejects_unknown_action():
    with pytest.raises(ValueError):
        protocol.encode_handshake(protocol.Handshake(action="reconnect"))


def test_result_roundtrip_preserves_session_and_error():
    h = MatrixHandle.fresh((2, 3), "float32")
    res = protocol.Result(values={"A": h}, elapsed=1.5,
                          error="KeyError: nope", session=9)
    back = protocol.decode_result(protocol.encode_result(res))
    assert back == res
    assert back.session == 9 and back.error == "KeyError: nope"


def test_command_session_roundtrip():
    cmd = protocol.Command("lib", "fn", {"k": 1}, session=12)
    assert protocol.decode_command(protocol.encode_command(cmd)).session == 12


# ---- session lifecycle ----
def test_connect_mints_distinct_sessions(engine):
    a = AlchemistContext(engine=engine, client_name="a")
    b = AlchemistContext(engine=engine, client_name="b")
    assert a.session != b.session
    assert a.session != SYSTEM_SESSION
    ids = {s.id for s in engine.sessions()}
    assert {SYSTEM_SESSION, a.session, b.session} <= ids


def test_wire_commands_cannot_claim_the_system_session(engine):
    """A client forging session=0 must not reach the system namespace."""
    engine.load_library("elemental", elemental)
    wire = protocol.encode_command(protocol.Command(
        "elemental", "random_matrix", {"rows": 4, "cols": 4}, session=0))
    res = protocol.decode_result(engine.run(wire))
    assert "system session" in res.error


def test_cross_session_free_raises_not_silently_noops(engine):
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    h = a.send_matrix(RNG.randn(4, 4)).handle
    with pytest.raises(KeyError, match="not visible"):
        b.free(h)
    assert engine.refcount(h) == 1


def test_command_for_unknown_session_errors(engine):
    engine.load_library("elemental", elemental)
    wire = protocol.encode_command(protocol.Command(
        "elemental", "random_matrix", {"rows": 4, "cols": 4}, session=999))
    res = protocol.decode_result(engine.run(wire))
    assert "UnknownSession" in res.error and res.session == 999


def test_engine_rejects_bogus_handshake_wire(engine):
    import msgpack

    res = protocol.decode_result(engine.handshake(
        msgpack.packb({"action": "party", "session": 0})))
    assert "ValueError" in res.error
    # the system session must survive any handshake
    assert any(s.id == SYSTEM_SESSION for s in engine.sessions())
    res2 = protocol.decode_result(engine.handshake(
        msgpack.packb({"action": "disconnect", "session": 0})))
    assert "system session" in res2.error


def test_nonpositive_chunk_rows_clamps_to_single_rows(engine):
    x = RNG.randn(10, 3).astype(np.float32)
    for bad in (0, -5):
        h, rec = transfer.to_engine(engine, x, chunk_rows=bad)
        assert rec.num_chunks == 10
        np.testing.assert_array_equal(np.asarray(engine.get(h)), x)


def test_disconnect_reclaims_session_handles(engine):
    ac = AlchemistContext(engine=engine)
    ac.send_matrix(RNG.randn(32, 8))
    ac.send_matrix(RNG.randn(16, 4))
    assert engine.resident_bytes() > 0
    ac.stop()
    assert engine.resident_bytes() == 0
    # session is gone from the table; stop() is idempotent
    assert all(s.id != ac.session for s in engine.sessions())
    ac.stop()


def test_free_session_counts_entries(engine):
    ac = AlchemistContext(engine=engine)
    ac.send_matrix(RNG.randn(8, 8))
    ac.send_matrix(RNG.randn(8, 8))
    assert engine.free_session(ac.session) == 2
    assert engine.free_session(ac.session) == 0


# ---- two concurrent sessions with isolated namespaces ----
def test_two_clients_full_flow_isolated(engine):
    """Acceptance: two contexts on one engine each send -> run -> fetch
    with isolated handle tables."""
    engine.load_library("elemental", elemental)
    engine.load_library("skylark", skylark)
    a = AlchemistContext(engine=engine, client_name="a")
    b = AlchemistContext(engine=engine, client_name="b")

    xa = RNG.randn(120, 24)
    al_a = a.send_matrix(xa)
    res_a = a.call("elemental", "truncated_svd", A=al_a, k=4)

    xb = RNG.randn(80, 10).astype(np.float32)
    yb = RNG.randn(80, 2).astype(np.float32)
    res_b = b.call("skylark", "cg_solve", X=b.send_matrix(xb),
                   Y=b.send_matrix(yb), lam=1e-3, max_iters=300, tol=1e-10)

    s = a.wrap(res_a["S"]).to_numpy().ravel()
    np.testing.assert_allclose(
        s, np.linalg.svd(xa, compute_uv=False)[:4], rtol=1e-4)
    w = b.wrap(res_b["W"]).to_numpy()
    want = np.linalg.solve(xb.T @ xb + 80 * 1e-3 * np.eye(10), xb.T @ yb)
    np.testing.assert_allclose(w, want, atol=1e-4)

    # cross-session access is refused at the dispatch boundary
    with pytest.raises(AlchemistError, match="not visible in session"):
        b.call("elemental", "qr", A=al_a.handle)
    with pytest.raises(KeyError, match="not visible"):
        b.fetch(al_a.handle)
    a.stop()
    b.stop()


def test_sessions_do_not_clobber_same_named_handles(engine):
    engine.load_library("elemental", elemental)
    a = AlchemistContext(engine=engine)
    b = AlchemistContext(engine=engine)
    ra = a.call("elemental", "random_matrix", rows=8, cols=8, seed=1,
                name="shared-name")
    rb = b.call("elemental", "random_matrix", rows=8, cols=8, seed=2,
                name="shared-name")
    assert ra["A"].id != rb["A"].id
    va = a.wrap(ra["A"]).to_numpy()
    vb = b.wrap(rb["A"]).to_numpy()
    assert not np.allclose(va, vb)


def test_serialized_dispatch_under_threads(engine):
    """Concurrent clients' commands all execute, strictly one at a time."""
    engine.load_library("elemental", elemental)
    ctxs = [AlchemistContext(engine=engine) for _ in range(3)]
    errors = []

    def work(ac, seed):
        try:
            for i in range(4):
                res = ac.call("elemental", "random_matrix", rows=16,
                              cols=8, seed=seed * 10 + i)
                g = ac.call("elemental", "gram", A=res["A"])
                assert g["G"].shape == (8, 8)
        except Exception as e:  # pragma: no cover - surfaced via errors
            errors.append(e)

    threads = [threading.Thread(target=work, args=(ac, i))
               for i, ac in enumerate(ctxs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    counts = {s.id: s.commands for s in engine.sessions()}
    assert all(counts[ac.session] == 8 for ac in ctxs)


# ---- chunked streaming transfer ----
@pytest.mark.parametrize("shape", [(1, 1), (7, 3), (103, 17), (128, 32),
                                   (257, 5)])
@pytest.mark.parametrize("chunk_rows", [1, 8, 37, 10_000])
def test_chunked_equals_single_shot_bit_exact(engine, shape, chunk_rows):
    x = RNG.randn(*shape).astype(np.float32)
    h_stream, rec = transfer.to_engine(engine, x, chunk_rows=chunk_rows)
    h_single, _ = transfer.to_engine(engine, x, chunk_rows=10**9)
    a = np.asarray(engine.get(h_stream))
    b = np.asarray(engine.get(h_single))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, x)
    expected_chunks = -(-shape[0] // chunk_rows)
    assert rec.num_chunks == expected_chunks


def test_unserializable_routine_output_errors_without_desyncing(engine):
    """A routine returning a value the protocol refuses to serialize must
    come back as an error Result, and the dispatch queue must keep
    serving later commands (one bad command cannot strand the queue)."""
    class _BadLib:
        ROUTINES = {"bad": lambda eng: {"A": np.zeros(3)}}

    engine.load_library("bad", _BadLib)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    with pytest.raises(AlchemistError, match="TypeError"):
        ac.call("bad", "bad")
    res = ac.call("elemental", "random_matrix", rows=4, cols=4)
    assert res["A"].shape == (4, 4)


def test_undecodable_wire_bytes_return_error_result(engine):
    res = protocol.decode_result(engine.run(b"\x00garbage"))
    assert res.error


def test_send_returns_aggregate_record(engine):
    """The returned record summarizes the whole stream, not one chunk."""
    x = RNG.randn(100, 10).astype(np.float32)
    _, rec = transfer.to_engine(engine, x, chunk_rows=33)
    assert rec.nbytes == x.nbytes
    assert rec.num_chunks == 4 and rec.chunk_index == -1
    assert rec.modeled_socket_s > 0


def test_rowmatrix_source_streams_without_collect(engine, monkeypatch):
    """A RowMatrix crosses partition-by-partition — collect() never runs."""
    from repro.frontend.rowmatrix import RowMatrix

    x = RNG.randn(60, 5)
    rm = RowMatrix.from_array(x, num_partitions=4)

    def _no_collect():
        raise AssertionError("collect() called")

    monkeypatch.setattr(rm, "collect", _no_collect)
    h, rec = transfer.to_engine(engine, rm, chunk_rows=7)
    # JAX canonicalizes f64 -> f32 on device_put (same as the old
    # single-shot jnp.asarray path), so compare against the f32 cast.
    np.testing.assert_array_equal(np.asarray(engine.get(h)),
                                  x.astype(np.float32))
    assert rec.nbytes == x.nbytes


def test_per_chunk_records_sum_to_matrix_bytes(engine):
    before = len(engine.transfer_log.records)
    x = RNG.randn(100, 10).astype(np.float32)
    transfer.to_engine(engine, x, chunk_rows=33, session=SYSTEM_SESSION)
    recs = engine.transfer_log.records[before:]
    assert len(recs) == 4                      # 33+33+33+1 rows
    assert sum(r.nbytes for r in recs) == x.nbytes
    assert [r.chunk_index for r in recs] == [0, 1, 2, 3]
    assert all(r.num_chunks == 4 for r in recs)


def test_fetch_streams_back_bit_exact(engine):
    ac = AlchemistContext(engine=engine, chunk_rows=9)
    x = RNG.randn(50, 11).astype(np.float32)
    al = ac.send_matrix(x)
    back = ac.fetch(al.handle, chunk_rows=13).collect()
    np.testing.assert_array_equal(back, x)


def test_rowmatrix_iter_row_blocks_rechunks():
    from repro.frontend.rowmatrix import RowMatrix

    x = RNG.randn(53, 4)
    rm = RowMatrix.from_array(x, num_partitions=7)
    blocks = list(rm.iter_row_blocks(10))
    assert [b.shape[0] for b in blocks] == [10, 10, 10, 10, 10, 3]
    np.testing.assert_array_equal(np.concatenate(blocks), x)


# ---- handle lifecycle: refcounts, LRU spill, reload ----
def test_session_can_read_but_not_free_system_handles(engine):
    h = engine.put(np.ones((4, 4), np.float32))    # system-owned
    ac = AlchemistContext(engine=engine)
    np.testing.assert_array_equal(                 # readable (shared input)
        engine.get(h, session=ac.session), np.ones((4, 4), np.float32))
    with pytest.raises(KeyError, match="may read"):
        ac.free(h)
    assert engine.refcount(h) == 1                 # untouched


def test_command_wire_requires_session_field():
    import msgpack

    wire = msgpack.packb({"library": "l", "routine": "r", "args": {}})
    with pytest.raises(KeyError):
        protocol.decode_command(wire)


def test_jax_array_input_takes_direct_path(engine):
    import jax.numpy as jnp

    before = len(engine.transfer_log.records)
    x = jnp.ones((64, 8), jnp.float32)
    h, rec = transfer.to_engine(engine, x, chunk_rows=4)
    assert len(engine.transfer_log.records) == before + 1   # one record
    assert rec.num_chunks == 1
    np.testing.assert_array_equal(np.asarray(engine.get(h)), np.asarray(x))


def test_refcount_retain_release(engine):
    h = engine.put(np.zeros((4, 4), np.float32))
    assert engine.refcount(h) == 1
    engine.retain(h)
    engine.free(h)
    assert engine.refcount(h) == 1             # still one ref left
    engine.get(h)                              # still resolvable
    engine.free(h)
    assert engine.refcount(h) == 0
    with pytest.raises(KeyError, match="not resident"):
        engine.get(h)


def test_lru_eviction_spills_oldest_and_reload_is_exact():
    nbytes = 100 * 100 * 4
    engine = AlchemistEngine(make_engine_mesh(1),
                             memory_budget_bytes=3 * nbytes)
    mats = [RNG.randn(100, 100).astype(np.float32) for _ in range(5)]
    handles = [engine.put(m) for m in mats]
    assert engine.resident_bytes() <= 3 * nbytes
    assert engine.spilled_bytes() == 2 * nbytes
    # the two least-recently-used (first puts) were spilled
    assert engine.is_spilled(handles[0]) and engine.is_spilled(handles[1])
    # transparent reload returns exact data and re-enforces the budget
    np.testing.assert_array_equal(np.asarray(engine.get(handles[0])),
                                  mats[0])
    assert not engine.is_spilled(handles[0])
    assert engine.resident_bytes() <= 3 * nbytes
    # every matrix survives arbitrary access order bit-exactly
    for h, m in zip(handles, mats):
        np.testing.assert_array_equal(np.asarray(engine.get(h)), m)


def test_eviction_interacts_with_routines():
    """A spilled input reloads transparently when a routine resolves it."""
    nbytes = 64 * 16 * 4
    engine = AlchemistEngine(make_engine_mesh(1),
                             memory_budget_bytes=2 * nbytes)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine)
    x = RNG.randn(64, 16).astype(np.float32)
    al = ac.send_matrix(x)
    ac.send_matrix(RNG.randn(64, 16))          # pressure
    ac.send_matrix(RNG.randn(64, 16))          # evicts al's array
    assert engine.is_spilled(al.handle)
    res = ac.call("elemental", "gram", A=al)
    g = ac.wrap(res["G"]).to_numpy()
    np.testing.assert_allclose(g, x.T @ x, atol=1e-3)
