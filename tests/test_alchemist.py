"""Alchemist system tests: context/handles/protocol/libraries — the paper's
§3 behaviours plus numerical correctness of every offloaded routine."""
import numpy as np
import pytest

from repro.core import AlchemistContext
from repro.core import protocol
from repro.core.context import AlchemistError
from repro.core.handles import MatrixHandle
from repro.core.libraries import elemental, mllib, skylark
from repro.frontend.rowmatrix import RowMatrix

RNG = np.random.RandomState(0)


@pytest.fixture()
def ac():
    ctx = AlchemistContext(num_workers=1)
    ctx.register_library("elemental", elemental)
    ctx.register_library("skylark", skylark)
    return ctx


def test_protocol_roundtrip_with_handles():
    h = MatrixHandle.fresh((3, 4), "float32", name="A")
    cmd = protocol.Command("lib", "routine", {"A": h, "k": 5, "tol": 1e-3},
                           session=7)
    back = protocol.decode_command(protocol.encode_command(cmd))
    assert back.routine == "routine" and back.session == 7
    assert back.args["A"] == h and back.args["k"] == 5


def test_protocol_rejects_arrays():
    with pytest.raises(TypeError):
        protocol.encode_command(protocol.Command(
            "lib", "r", {"A": np.zeros(3)}))


def test_unknown_library_and_routine_error(ac):
    with pytest.raises(AlchemistError, match="not registered"):
        ac.call("nope", "qr")
    with pytest.raises(AlchemistError, match="not in"):
        ac.call("elemental", "nope")


def test_stopped_context_refuses_calls(ac):
    ac.stop()
    with pytest.raises(AlchemistError):
        ac.call("elemental", "qr")


def test_engine_side_error_propagates(ac):
    ghost = MatrixHandle.fresh((3, 3), "float32")
    with pytest.raises(AlchemistError, match="KeyError"):
        ac.call("elemental", "qr", A=ghost)


def test_qr_decomposition(ac):
    a = RNG.randn(200, 50)
    res = ac.call("elemental", "qr", A=ac.send_matrix(a))
    q = ac.wrap(res["Q"]).to_numpy()
    r = ac.wrap(res["R"]).to_numpy()
    np.testing.assert_allclose(q @ r, a, atol=1e-4)
    np.testing.assert_allclose(q.T @ q, np.eye(50), atol=1e-4)


def test_truncated_svd_matches_numpy(ac):
    x = RNG.randn(400, 60) @ np.diag(np.geomspace(10, 0.01, 60))
    res = ac.call("elemental", "truncated_svd", A=ac.send_matrix(x), k=8)
    s = ac.wrap(res["S"]).to_numpy().ravel()
    want = np.linalg.svd(x, compute_uv=False)[:8]
    np.testing.assert_allclose(s, want, rtol=1e-4)
    u = ac.wrap(res["U"]).to_numpy()
    v = ac.wrap(res["V"]).to_numpy()
    np.testing.assert_allclose(u @ np.diag(s) @ v.T,
                               (np.linalg.svd(x)[0][:, :8] * want)
                               @ np.linalg.svd(x)[2][:8],
                               atol=1e-3 * want[0])


def test_gram_svd_matches_numpy_and_uses_kernel(ac):
    """The Pallas-gram path (interpret mode) through the library layer."""
    x = RNG.randn(512, 96) @ np.diag(np.geomspace(8, 0.05, 96))
    res = ac.call("elemental", "gram_svd", A=ac.send_matrix(x), k=6,
                  use_pallas=True)
    s = ac.wrap(res["S"]).to_numpy().ravel()
    want = np.linalg.svd(x, compute_uv=False)[:6]
    np.testing.assert_allclose(s, want, rtol=1e-3)


def test_randomized_svd_close_to_numpy(ac):
    x = RNG.randn(300, 50) @ np.diag(np.geomspace(5, 0.001, 50))
    res = ac.call("elemental", "randomized_svd", A=ac.send_matrix(x), k=5,
                  power_iters=3)
    s = ac.wrap(res["S"]).to_numpy().ravel()
    want = np.linalg.svd(x, compute_uv=False)[:5]
    np.testing.assert_allclose(s, want, rtol=1e-3)


def test_cg_solves_normal_equations(ac):
    x = RNG.randn(300, 20)
    y = RNG.randn(300, 3)
    lam = 1e-3
    res = ac.call("skylark", "cg_solve", X=ac.send_matrix(x),
                  Y=ac.send_matrix(y), lam=lam, max_iters=500, tol=1e-10)
    w = ac.wrap(res["W"]).to_numpy()
    want = np.linalg.solve(x.T @ x + 300 * lam * np.eye(20), x.T @ y)
    np.testing.assert_allclose(w, want, atol=1e-5)
    assert res["iterations"] <= 25
    # residual history is monotone-ish and ends tiny
    assert res["residual_history"][-1] < 1e-9


def test_cg_with_engine_side_rf_expansion(ac):
    """The paper's §4.1 flow: only the raw (n x d) matrix crosses the
    bridge; the expansion to rf_dim happens engine-side."""
    x = RNG.randn(200, 10)
    y = RNG.randn(200, 2)
    bytes_before = ac.engine.transfer_log.total_bytes
    res = ac.call("skylark", "cg_solve", X=ac.send_matrix(x),
                  Y=ac.send_matrix(y), lam=1e-3, rf_dim=128, max_iters=400,
                  tol=1e-9)
    sent = ac.engine.transfer_log.total_bytes - bytes_before
    assert res["expanded_dim"] == 128
    assert sent < 1.1 * (x.nbytes + y.nbytes)     # expansion did NOT cross
    assert res["relative_residual"] < 1e-6


def test_handle_chaining_stays_engine_side(ac):
    """random_matrix -> gram -> qr without any client materialization."""
    res = ac.call("elemental", "random_matrix", rows=128, cols=32, seed=1)
    n_transfers = len(ac.engine.transfer_log.records)
    res2 = ac.call("elemental", "gram", A=res["A"])
    res3 = ac.call("elemental", "qr", A=res2["G"])
    assert len(ac.engine.transfer_log.records) == n_transfers  # no crossing
    assert res3["Q"].shape == (32, 32)


def test_replicate_cols_weak_scaling_shape(ac):
    res = ac.call("elemental", "random_matrix", rows=64, cols=16)
    res2 = ac.call("elemental", "replicate_cols", A=res["A"], times=4)
    assert res2["A"].shape == (64, 64)


def test_free_releases_engine_memory(ac):
    al = ac.send_matrix(RNG.randn(100, 100))
    before = ac.engine.resident_bytes()
    al.free()
    assert ac.engine.resident_bytes() < before


def test_spark_baseline_agrees_with_alchemist(ac):
    """Both sides of the paper's comparison must compute the same answer."""
    x = RNG.randn(250, 15)
    y = RNG.randn(250, 2)
    res = ac.call("skylark", "cg_solve", X=ac.send_matrix(x),
                  Y=ac.send_matrix(y), lam=1e-3, max_iters=500, tol=1e-12)
    w_alch = ac.wrap(res["W"]).to_numpy()
    w_spark, stats = mllib.spark_cg_solve(
        RowMatrix.from_array(x, 4), RowMatrix.from_array(y, 4),
        lam=1e-3, max_iters=500, tol=1e-12)
    np.testing.assert_allclose(w_alch, w_spark, atol=1e-5)
    assert stats["bsp_rounds"] >= stats["iterations"]


def test_concurrent_sessions_share_engine():
    engine_ctx = AlchemistContext(num_workers=1)
    engine_ctx.register_library("elemental", elemental)
    ac2 = AlchemistContext(engine=engine_ctx.engine)
    assert ac2.session != engine_ctx.session
    res = ac2.call("elemental", "random_matrix", rows=8, cols=8)
    assert res["A"].shape == (8, 8)


def test_mllib_stats_dict_contract():
    """Both pure-Spark entry points report the same accounting contract:
    measured wall time, BSP round count, and the Table-2-calibrated
    modeled per-round cost under one shared key name."""
    x = RowMatrix.from_array(RNG.randn(120, 10), 4)
    y = RowMatrix.from_array(RNG.randn(120, 2), 4)

    _, cg_stats = mllib.spark_cg_solve(x, y, lam=1e-3, max_iters=50)
    assert set(cg_stats) == {"iterations", "bsp_rounds",
                             "relative_residual", "measured_seconds",
                             "modeled_iteration_seconds"}

    _, _, svd_stats = mllib.spark_truncated_svd(x, k=3)
    assert set(svd_stats) == {"bsp_rounds", "measured_seconds",
                              "modeled_iteration_seconds", "lanczos_iters"}
    assert "modeled_round_overhead_seconds" not in svd_stats

    for stats in (cg_stats, svd_stats):
        assert stats["bsp_rounds"] >= 1
        assert stats["measured_seconds"] > 0
        assert stats["modeled_iteration_seconds"] > 0
    # the modeled per-round cost is the same quantity in both entry
    # points: identical (nodes, shape) must price identically
    _, cg12 = mllib.spark_cg_solve(x, y, lam=1e-3, max_iters=5, nodes=12)
    _, _, svd12 = mllib.spark_truncated_svd(x, k=3, nodes=12)
    assert cg12["modeled_iteration_seconds"] == \
        svd12["modeled_iteration_seconds"]
