"""Client-side substrate: RDD lineage/fault-tolerance and RowMatrix ops."""
import numpy as np

from repro.frontend.rdd import RDD
from repro.frontend.rowmatrix import RowMatrix


def test_rdd_lineage_recomputes_lost_partition():
    calls = {"n": 0}

    def gen(i):
        calls["n"] += 1
        rng = np.random.RandomState(i)
        return rng.randn(4, 3)

    rdd = RDD.from_generator(4, gen).cache()
    data = rdd.collect()
    assert calls["n"] == 4
    rdd.partition(2)                        # cached: no recompute
    assert calls["n"] == 4
    rdd.lose_partition(2)                   # executor failure
    recovered = rdd.partition(2)
    assert calls["n"] == 5
    np.testing.assert_array_equal(recovered, data[2])  # lineage-identical


def test_rdd_map_is_lazy_and_composes():
    evals = {"n": 0}

    def gen(i):
        evals["n"] += 1
        return np.full((2, 2), float(i))

    doubled = RDD.from_generator(3, gen).map_partitions(lambda x: 2 * x)
    assert evals["n"] == 0                  # nothing computed yet
    out = doubled.collect()
    assert evals["n"] == 3
    np.testing.assert_array_equal(out[2], np.full((2, 2), 4.0))


def test_rowmatrix_roundtrip_and_gram():
    a = np.random.RandomState(0).randn(50, 7)
    m = RowMatrix.from_array(a, 5)
    np.testing.assert_array_equal(m.collect(), a)
    w = np.random.RandomState(1).randn(7, 2)
    np.testing.assert_allclose(m.gram_times(w), a.T @ (a @ w), atol=1e-10)


def test_rowmatrix_random_is_reproducible():
    m1 = RowMatrix.random(40, 5, num_partitions=4, seed=3)
    m2 = RowMatrix.random(40, 5, num_partitions=4, seed=3)
    np.testing.assert_array_equal(m1.collect(), m2.collect())
    m1.rdd.lose_partition(1)
    np.testing.assert_array_equal(m1.collect(), m2.collect())


def test_map_rows_is_lazy_and_derives_width_from_output():
    """map_rows must not eagerly re-invoke fn on partition 0; the output
    width comes from the mapped lineage (1-D outputs count as 1 col)."""
    import numpy as np
    from repro.frontend.rowmatrix import RowMatrix

    x = np.arange(24, dtype=np.float64).reshape(12, 2)
    rm = RowMatrix.from_array(x, num_partitions=3)
    calls = []

    def double_cols(block):
        calls.append(block.shape)
        return np.hstack([block, block])

    mapped = rm.map_rows(double_cols)
    assert calls == []                     # construction ran nothing
    assert mapped.num_cols == 4            # lazily derived on access
    np.testing.assert_array_equal(mapped.collect(), np.hstack([x, x]))
    # fn ran exactly once per partition: the num_cols peek memoizes the
    # partition-0 realization it forced, and collect() reuses it
    assert len(calls) == 3

    # 1-D outputs no longer crash: convention matches from_array
    norms = rm.map_rows(lambda b: np.linalg.norm(b, axis=1))
    assert norms.num_cols == 1
