"""Deliberately-broken module exercised by tests/test_analysis.py.

Every construct below violates exactly one repro.analysis source rule;
the tests assert each rule fires *here* and stays quiet on the real
tree. This module must never be imported by product code (and the
pickle import is why it must never be imported at all by the tests —
they parse it as source).
"""
import pickle                                          # PKL001
import threading

import jax
import numpy as np


def evil_loads(payload: bytes):
    return pickle.loads(payload)                       # PKL001 (call)


_lock = threading.Lock()                               # LCK001


@jax.jit
def impure_traced(x):
    print("tracing", x)                                # TRC001 (I/O)
    host = np.asarray(x)                               # TRC001 (host sync)
    x.block_until_ready()                              # TRC001 (sync)
    with _lock:                                        # TRC001 (locking)
        return host + 1


def _bad_kernel(x_ref, o_ref):
    print("inside a pallas kernel")                    # TRC001 (I/O)
    o_ref[...] = x_ref[...]


def launch(pallas_call, x):
    return pallas_call(_bad_kernel, out_shape=x)(x)
