"""Deliberately-broken lifecycle module exercised by
tests/test_analysis.py (parsed as source against a crafted Machine
spec, never imported — like analysis_violations.py).

The crafted ``fx`` machine declares: guarded field ``_rows`` owned by
``self._lk``, mint site ``open_row``, edge OPEN->CLOSED at
``close_row`` (which is obligated to call ``unhook``), and a declared
site ``ghost_site`` that does not exist below. Each construct violates
exactly one STM rule; the test asserts each fires *here* and stays
quiet on the real tree.
"""


class BrokenFx:
    def open_row(self, k):
        with self._lk:
            self._rows[k] = "OPEN"          # declared site, locked: clean

    def close_row(self, k):
        self._rows.pop(k)                   # STM003: outside self._lk
        # STM004: never calls the obligated unhook()

    def rogue_drop(self, k):
        with self._lk:
            del self._rows[k]               # STM001: undeclared site
# STM002: the spec's ghost_site has no function here
