"""Compile-latency subsystem (``core/compilecache.py``): bucket-policy
units, pad/crop conformance of every bucketable cataloged routine
against the reference backend at odd (non-bucket) shapes, shape-aware
plan signatures, the program-cache LRU bound, AOT warmup, the
persistent executable index + warm-restart zero-recompile round trip,
fused chains with bucketing on/off, CompileLog accounting, and the
``configure`` wire surface (bucketing/warmup/cache_dir options)."""
import threading

import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine
from repro.core import compilecache
from repro.core.backends import base as backend_base
from repro.core.backends.jax_backend import JaxBackend
from repro.core.context import AlchemistError
from repro.core.engine import make_engine_mesh
from repro.core.handles import MatrixHandle
from repro.core.libraries import elemental

RNG = np.random.RandomState(11)

# deliberately odd, off-grid shapes: every dimension pads under the
# default pow2 bucket grid
ODD_A = RNG.randn(37, 53).astype(np.float32)
ODD_B = RNG.randn(53, 29).astype(np.float32)
ODD_C = RNG.randn(37, 53).astype(np.float32)
ODD_SQ = (RNG.randn(19, 19) / 4.0).astype(np.float32)


def fresh(cache_entries=0, **engine_kw):
    engine = AlchemistEngine(make_engine_mesh(1),
                             cache_entries=cache_entries, **engine_kw)
    engine.load_library("elemental", elemental)
    return engine


# ---------------------------------------------------------------------------
# BucketPolicy units
# ---------------------------------------------------------------------------
def test_bucket_dim_rounds_up_to_smallest_holding_bucket():
    p = compilecache.BucketPolicy(grid=(32, 64, 128))
    assert p.bucket_dim(1) == 32
    assert p.bucket_dim(32) == 32      # exact boundary stays
    assert p.bucket_dim(33) == 64
    assert p.bucket_dim(128) == 128
    assert p.bucket_dim(129) == 129    # beyond grid: passthrough


def test_bucket_shape_and_exactness():
    p = compilecache.BucketPolicy(grid=(32, 64))
    assert p.bucket_shape((37, 53)) == (64, 64)
    assert p.bucket_shape((32, 64)) == (32, 64)
    assert p.is_exact((32, 64))
    assert not p.is_exact((37, 53))


def test_disabled_policy_is_identity():
    p = compilecache.BucketPolicy(grid=(32, 64), enabled=False)
    assert p.bucket_dim(37) == 37
    assert p.bucket_shape((37, 53)) == (37, 53)
    assert p.is_exact((37, 53))


def test_bucket_grid_is_sorted_and_validated():
    p = compilecache.BucketPolicy(grid=(128, 32, 64))
    assert p.grid == (32, 64, 128)
    with pytest.raises(ValueError, match="positive"):
        compilecache.BucketPolicy(grid=(0, 32))


# ---------------------------------------------------------------------------
# pad/crop primitives
# ---------------------------------------------------------------------------
def test_pad_to_zero_pads_trailing_edges_and_crop_inverts():
    be = JaxBackend()
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    padded = np.asarray(be.pad_to(a, (4, 8)))
    assert padded.shape == (4, 8)
    np.testing.assert_array_equal(padded[:2, :3], a)
    assert float(np.abs(padded[2:, :]).sum()) == 0.0
    assert float(np.abs(padded[:, 3:]).sum()) == 0.0
    back = np.asarray(be.crop_to(padded, (2, 3)))
    np.testing.assert_array_equal(back, a)


def test_pad_to_rejects_shrinking_targets():
    be = JaxBackend()
    a = np.zeros((4, 4), dtype=np.float32)
    with pytest.raises(ValueError):
        be.pad_to(a, (2, 8))
    with pytest.raises(ValueError):
        be.pad_to(a, (4, 4, 4))


# ---------------------------------------------------------------------------
# bucket-padding conformance: every bucketable cataloged routine,
# bucketed jax vs exact reference, at odd shapes
# ---------------------------------------------------------------------------
# per-routine odd-shape operand sets satisfying each routine's shape rule
BUCKETABLE_CASES = {
    ("elemental", "multiply"): {"A": ODD_A, "B": ODD_B},
    ("elemental", "add"): {"A": ODD_A, "B": ODD_C},
    ("elemental", "transpose"): {"A": ODD_A},
    ("elemental", "gram"): {"A": ODD_A},
}


def test_bucketable_catalog_is_fully_covered():
    """Every routine the jax backend declares bucketable has a
    conformance case here — a new bucketable registration must add one."""
    engine = fresh()
    try:
        be = engine.backends["jax"]
        declared = {(lib, rn) for lib, rn in be.routines()
                    if be.routine_impl(lib, rn).bucketable}
        assert declared == set(BUCKETABLE_CASES)
        # and the reference backend declares the identical bucketable set
        ref = engine.backends["reference"]
        assert declared == {(lib, rn) for lib, rn in ref.routines()
                            if ref.routine_impl(lib, rn).bucketable}
    finally:
        engine.shutdown()


@pytest.mark.parametrize("lib,rn", sorted(BUCKETABLE_CASES))
def test_bucketed_result_identical_to_reference(lib, rn):
    engine = fresh(bucketing=True)
    ac_jax = AlchemistContext(engine=engine)
    ac_ref = AlchemistContext(engine=engine, backend="reference")
    try:
        arrays = BUCKETABLE_CASES[(lib, rn)]
        outs = {}
        for ac in (ac_jax, ac_ref):
            handles = {k: ac.send_matrix(v, dedup=False)
                       for k, v in arrays.items()}
            res = ac.call(lib, rn, **handles)
            outs[ac] = {k: (ac.fetch(v).collect(),
                            tuple(v.shape), v.dtype, v.layout)
                        for k, v in res.items()
                        if isinstance(v, MatrixHandle)}
        assert set(outs[ac_jax]) == set(outs[ac_ref])
        for k in outs[ac_jax]:
            arr_j, shape_j, dtype_j, layout_j = outs[ac_jax][k]
            arr_r, shape_r, dtype_r, layout_r = outs[ac_ref][k]
            # padded program outputs are cropped back to logical shapes
            assert (shape_j, dtype_j, layout_j) == \
                (shape_r, dtype_r, layout_r)
            np.testing.assert_allclose(arr_j, arr_r, rtol=1e-4, atol=1e-4)
        # the jax run actually exercised the bucket path
        assert engine.compile_log.stats()["bucketed_executions"] >= 1
    finally:
        ac_jax.stop()
        ac_ref.stop()
        engine.shutdown()


def test_non_bucketable_routine_unaffected_by_bucketing():
    """qr's values depend on operand extents — it must run at its exact
    shape even with bucketing on, and still conform to reference."""
    engine = fresh(bucketing=True)
    ac_jax = AlchemistContext(engine=engine)
    ac_ref = AlchemistContext(engine=engine, backend="reference")
    try:
        assert not engine.backends["jax"].routine_impl(
            "elemental", "qr").bucketable
        outs = {}
        for ac in (ac_jax, ac_ref):
            h = ac.send_matrix(ODD_A, dedup=False)
            res = ac.call("elemental", "qr", A=h)
            outs[ac] = {k: ac.fetch(v).collect() for k, v in res.items()
                        if isinstance(v, MatrixHandle)}
        for k in outs[ac_jax]:
            assert outs[ac_jax][k].shape == outs[ac_ref][k].shape
        # Q@R reconstructs A on both
        for ac in (ac_jax, ac_ref):
            np.testing.assert_allclose(
                outs[ac]["Q"] @ outs[ac]["R"], ODD_A,
                rtol=1e-3, atol=1e-3)
    finally:
        ac_jax.stop()
        ac_ref.stop()
        engine.shutdown()


# ---------------------------------------------------------------------------
# shape-aware plan signatures
# ---------------------------------------------------------------------------
def _plan(impl, shapes, dtype="float32"):
    args = {}
    specs = {}
    for n, (param, shape) in enumerate(sorted(shapes.items())):
        slot = f"i{n}"
        args[param] = backend_base.Input(slot)
        specs[slot] = (tuple(shape), dtype)
    return backend_base.ExecutionPlan(
        steps=[backend_base.PlanStep(library="elemental",
                                     routine="multiply", args=args,
                                     impl=impl)],
        input_specs=specs)


def test_signature_carries_operand_shapes_and_dtypes():
    be = JaxBackend()
    impl = be.routine_impl("elemental", "multiply")
    s1 = _plan(impl, {"A": (32, 32), "B": (32, 32)}).signature()
    s2 = _plan(impl, {"A": (64, 64), "B": (64, 64)}).signature()
    s3 = _plan(impl, {"A": (32, 32), "B": (32, 32)}).signature()
    s4 = _plan(impl, {"A": (32, 32), "B": (32, 32)},
               dtype="float64").signature()
    assert s1 != s2          # same structure, different shapes
    assert s1 == s3          # stable across rebuilds
    assert s1 != s4          # dtype is part of the identity
    hash(s1)                 # usable as a cache key


def test_signature_none_without_specs_is_distinct_key_shape():
    be = JaxBackend()
    impl = be.routine_impl("elemental", "multiply")
    plan = _plan(impl, {"A": (32, 32), "B": (32, 32)})
    plan.input_specs = None
    sig = plan.signature()
    assert sig is not None and sig[1] is None
    plan.steps[0].args["B"] = [1, 2]        # unhashable arg
    assert plan.signature() is None


# ---------------------------------------------------------------------------
# shape propagation (the crop-back contract)
# ---------------------------------------------------------------------------
def test_propagate_shapes_through_a_chain():
    be = JaxBackend()
    mul = be.routine_impl("elemental", "multiply")
    gram = be.routine_impl("elemental", "gram")
    plan = backend_base.ExecutionPlan(steps=[
        backend_base.PlanStep(
            library="elemental", routine="multiply",
            args={"A": backend_base.Input("i0"),
                  "B": backend_base.Input("i1")}, impl=mul),
        backend_base.PlanStep(
            library="elemental", routine="gram",
            args={"A": backend_base.StepRef(0, "C")}, impl=gram),
    ])
    crops = compilecache.propagate_shapes(
        plan, {"i0": (37, 53), "i1": (53, 29)})
    assert crops == [{"C": (37, 29)}, {"G": (29, 29)}]
    # a rule that rejects the shapes -> None, caller runs exact
    assert compilecache.propagate_shapes(
        plan, {"i0": (37, 53), "i1": (31, 29)}) is None
    assert compilecache.plan_bucketable(plan)


def test_plan_with_non_bucketable_step_is_not_bucketable():
    be = JaxBackend()
    mul = be.routine_impl("elemental", "multiply")
    qr = be.routine_impl("elemental", "qr")
    plan = backend_base.ExecutionPlan(steps=[
        backend_base.PlanStep(
            library="elemental", routine="multiply",
            args={"A": backend_base.Input("i0"),
                  "B": backend_base.Input("i1")}, impl=mul),
        backend_base.PlanStep(
            library="elemental", routine="qr",
            args={"A": backend_base.StepRef(0, "C")}, impl=qr),
    ])
    assert not compilecache.plan_bucketable(plan)


# ---------------------------------------------------------------------------
# warmup enumeration
# ---------------------------------------------------------------------------
def test_matrix_params_discovered_from_shape_rules():
    be = JaxBackend()
    assert compilecache.matrix_params_of(
        be.routine_impl("elemental", "multiply")) == ["A", "B"]
    assert compilecache.matrix_params_of(
        be.routine_impl("elemental", "gram")) == ["A"]
    assert compilecache.matrix_params_of(
        be.routine_impl("elemental", "qr")) == []


def test_warmup_shape_sets_respect_the_shape_rule():
    be = JaxBackend()
    mul = be.routine_impl("elemental", "multiply")
    combos = compilecache.warmup_shape_sets(mul, ["A", "B"], (32, 64),
                                            limit=1000)
    assert combos
    for c in combos:
        assert c["A"][1] == c["B"][0]       # contracted dims agree
    # 2 grid sizes: A has 4 shapes, B's rows pinned by A's cols -> 2 each
    assert len(combos) == 8
    add = be.routine_impl("elemental", "add")
    for c in compilecache.warmup_shape_sets(add, ["A", "B"], (32, 64),
                                            limit=1000):
        assert c["A"] == c["B"]
    # the enumeration ceiling holds
    assert len(compilecache.warmup_shape_sets(
        mul, ["A", "B"], (32, 64, 128, 256), limit=5)) == 5


# ---------------------------------------------------------------------------
# program-cache LRU bound
# ---------------------------------------------------------------------------
def test_program_cache_lru_evicts_oldest_and_counts():
    be = JaxBackend(max_programs=2)
    impl = be.routine_impl("elemental", "multiply")
    plans = [_plan(impl, {"A": (s, s), "B": (s, s)})
             for s in (8, 16, 32)]
    for p in plans:
        _, info = be.get_or_compile(p)
        assert not info["cached"]
    info = be.program_cache_info()
    assert info["programs"] == 2
    assert info["evictions"] == 1
    # oldest (8x8) was evicted -> recompiles; newest (32x32) still hot
    _, i32 = be.get_or_compile(plans[2])
    assert i32["cached"]
    _, i8 = be.get_or_compile(plans[0])
    assert not i8["cached"]
    assert be.evictions == 2                # recompile evicted 16x16


def test_aot_compiled_program_executes_without_retrace():
    be = JaxBackend()
    impl = be.routine_impl("elemental", "multiply")
    plan = _plan(impl, {"A": (8, 8), "B": (8, 8)})
    program, info = be.get_or_compile(plan)
    assert info["aot"] and not info["cached"] and info["compile_s"] > 0
    a = np.eye(8, dtype=np.float32)
    outs = program({"i0": a, "i1": a * 2.0})
    np.testing.assert_allclose(np.asarray(outs[0]["C"]), a * 2.0)


# ---------------------------------------------------------------------------
# executable index
# ---------------------------------------------------------------------------
def test_executable_index_round_trips_plans(tmp_path):
    be = JaxBackend()
    impl = be.routine_impl("elemental", "multiply")
    plan = _plan(impl, {"A": (32, 16), "B": (16, 8)})
    idx = compilecache.ExecutableIndex(str(tmp_path))
    assert idx.record("jax", plan, compile_s=0.5)
    assert not idx.record("jax", plan)       # re-record is a no-op
    assert len(idx) == 1
    # reload from disk and rebuild the plan against a live backend
    idx2 = compilecache.ExecutableIndex(str(tmp_path))
    [rec] = idx2.entries(backend="jax")
    assert rec["label"] == "elemental.multiply"
    rebuilt = compilecache.plan_from_record(rec, be)
    assert rebuilt is not None
    assert rebuilt.signature() == plan.signature()
    assert idx2.entries(backend="reference") == []


def test_executable_index_concurrent_engines_merge_not_clobber(tmp_path):
    """Two engines sharing a cache dir each loaded the index before the
    other recorded: without merge-on-write the second save clobbers the
    first engine's record (last-write-wins). Both must survive."""
    be = JaxBackend()
    impl = be.routine_impl("elemental", "multiply")
    plan_a = _plan(impl, {"A": (32, 16), "B": (16, 8)})
    plan_b = _plan(impl, {"A": (64, 32), "B": (32, 8)})
    idx1 = compilecache.ExecutableIndex(str(tmp_path))
    idx2 = compilecache.ExecutableIndex(str(tmp_path))  # both loaded empty
    assert idx1.record("jax", plan_a)
    assert idx2.record("jax", plan_b)   # must fold idx1's record in
    fresh = compilecache.ExecutableIndex(str(tmp_path))
    labels = sorted((r["key"] for r in fresh.entries()))
    assert len(fresh) == 2
    assert {r["key"] for r in idx1.entries()} <= set(labels)

    # threaded stress: interleaved writers through separate instances
    # never lose a record
    shapes = [( (16 * (i + 1), 8), (8, 4) ) for i in range(8)]
    plans = [_plan(impl, {"A": sa, "B": sb}) for sa, sb in shapes]
    writers = [compilecache.ExecutableIndex(str(tmp_path))
               for _ in range(2)]
    threads = [
        threading.Thread(target=lambda w=writers[i % 2], p=p:
                         w.record("jax", p))
        for i, p in enumerate(plans)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(compilecache.ExecutableIndex(str(tmp_path))) == 2 + len(plans)


def test_executable_index_skips_unserializable_plans(tmp_path):
    be = JaxBackend()
    impl = be.routine_impl("elemental", "multiply")
    plan = _plan(impl, {"A": (8, 8), "B": (8, 8)})
    plan.input_specs = None                  # shape-blind: not replayable
    idx = compilecache.ExecutableIndex(str(tmp_path))
    assert not idx.record("jax", plan)
    assert len(idx) == 0


# ---------------------------------------------------------------------------
# CompileLog accounting
# ---------------------------------------------------------------------------
def test_compile_log_separates_request_from_warmup():
    from repro.core.costmodel import CompileLog

    log = CompileLog()
    log.record(1, "elemental.multiply", "compile", aot=True,
               bucketed=True, compile_s=0.5)
    log.record(-1, "elemental.gram", "compile", aot=True,
               on_request_path=False, compile_s=0.2)
    log.record(1, "elemental.multiply", "hit", bucketed=True)
    log.record(1, "elemental.multiply", "evict", count=2)
    s = log.stats()
    assert s["compiles"] == 2
    assert s["hits"] == 1
    assert s["request_compiles"] == 1
    assert s["warmup_compiles"] == 1
    assert s["request_compile_s"] == pytest.approx(0.5)
    assert s["warmup_compile_s"] == pytest.approx(0.2)
    assert s["bucketed_executions"] == 2
    assert s["bucketed_request_compiles"] == 1
    assert s["evictions"] == 2
    assert s["hit_rate"] == pytest.approx(1 / 3)
    per = log.session_summary(1)
    assert per["compiles"] == 1 and per["warmup_compiles"] == 0
    assert set(log.sessions()) == {1, -1}


# ---------------------------------------------------------------------------
# engine warmup: catalog AOT off the request path
# ---------------------------------------------------------------------------
def test_warmup_precompiles_catalog_and_absorbs_first_calls():
    # engine bucket grid == warmup grid: every odd dim pads to 64, so
    # the warmed 64-combos absorb ALL first calls (a warmup grid
    # narrower than the bucket grid only absorbs its own buckets)
    engine = fresh(bucketing=True, bucket_grid=(64,))
    ac = AlchemistContext(engine=engine)
    try:
        stats = engine.warmup(grid=(64,))
        assert stats["catalog"] >= len(BUCKETABLE_CASES)
        assert stats["compiled"] >= len(BUCKETABLE_CASES)
        log0 = engine.compile_log.stats()
        assert log0["warmup_compiles"] == stats["compiled"]
        assert log0["request_compiles"] == 0
        # first tenant calls at odd shapes bucketing to 64: all absorbed
        ha = ac.send_matrix(ODD_A, dedup=False)
        hb = ac.send_matrix(ODD_B, dedup=False)
        ac.call("elemental", "multiply", A=ha, B=hb)
        ac.call("elemental", "gram", A=ha)
        ac.call("elemental", "transpose", A=ha)
        log = engine.compile_log.stats()
        assert log["request_compiles"] == 0, log
        assert log["bucketed_request_compiles"] == 0
        assert log["hits"] >= 3
    finally:
        ac.stop()
        engine.shutdown()


def test_warmup_on_load_runs_in_background():
    engine = AlchemistEngine(make_engine_mesh(1), cache_entries=0,
                             warmup_on_load=True, warmup_grid=(32,))
    try:
        engine.load_library("elemental", elemental)
        engine.wait_warmup()
        s = engine.compile_log.stats()
        assert s["warmup_compiles"] >= len(BUCKETABLE_CASES)
        assert s["request_compiles"] == 0
    finally:
        engine.shutdown()


# ---------------------------------------------------------------------------
# persistence: warm-restart zero-recompile round trip
# ---------------------------------------------------------------------------
def test_warm_restart_replays_index_and_absorbs_requests(tmp_path):
    cache_dir = str(tmp_path / "ccache")

    def serve_one(eng):
        ac = AlchemistContext(engine=eng)
        try:
            ha = ac.send_matrix(ODD_A, dedup=False)
            hb = ac.send_matrix(ODD_B, dedup=False)
            res = ac.call("elemental", "multiply", A=ha, B=hb)
            return ac.fetch(res["C"]).collect()
        finally:
            ac.stop()

    # cold engine: the request-path compile lands in the index
    eng1 = fresh(compile_cache_dir=cache_dir, bucketing=True)
    try:
        out1 = serve_one(eng1)
        assert eng1.compile_log.stats()["request_compiles"] == 1
        assert len(eng1._exec_index) >= 1
    finally:
        eng1.shutdown()

    # restarted engine, same dir: warmup replays the index; the same
    # tenant traffic then sees ZERO request-path compiles
    eng2 = fresh(compile_cache_dir=cache_dir, bucketing=True)
    try:
        stats = eng2.warmup()
        assert stats["replayed"] >= 1
        out2 = serve_one(eng2)
        log = eng2.compile_log.stats()
        assert log["request_compiles"] == 0, log
        assert log["hits"] >= 1
        np.testing.assert_allclose(out2, out1, rtol=1e-5)
    finally:
        eng2.shutdown()


# ---------------------------------------------------------------------------
# fused chains: results unchanged bucketing on/off
# ---------------------------------------------------------------------------
def _burst_chain(ac, stages=3):
    el = ac.library("elemental")
    al = ac.send_matrix(ODD_SQ, dedup=False)
    ac.engine.scheduler.pause()
    x = al
    for _ in range(stages):
        x = el.multiply(A=x, B=al)
    ac.engine.scheduler.resume()
    return x.to_numpy()


def _settled_task_stats(engine, commands, timeout=5.0):
    """Task-log records land via the scheduler completion hook, slightly
    after the client sees the result — poll until every command's record
    arrived before asserting on the accounting."""
    import time as _time

    deadline = _time.monotonic() + timeout
    while _time.monotonic() < deadline:
        s = engine.task_log.stats()
        if s["commands"] >= commands:
            return s
        _time.sleep(0.01)
    return engine.task_log.stats()


@pytest.mark.parametrize("bucketing", [True, False])
def test_fused_chain_results_unchanged_by_bucketing(bucketing):
    engine = fresh(bucketing=bucketing)
    ac = AlchemistContext(engine=engine)
    try:
        got = _burst_chain(ac)
        want = ODD_SQ
        for _ in range(3):
            want = want @ ODD_SQ
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
        stats = _settled_task_stats(engine, commands=3)
        assert stats["fused_tasks"] >= 1, stats   # the chain really fused
        log = engine.compile_log.stats()
        if bucketing:
            assert log["bucketed_executions"] >= 1
        else:
            assert log["bucketed_executions"] == 0
    finally:
        ac.stop()
        engine.shutdown()


def test_session_bucketing_override_vs_engine_default():
    engine = fresh(bucketing=True)
    ac_off = AlchemistContext(engine=engine, bucketing=False)
    ac_on = AlchemistContext(engine=engine)
    try:
        ha = ac_off.send_matrix(ODD_A, dedup=False)
        ac_off.call("elemental", "gram", A=ha)
        assert engine.compile_log.stats()["bucketed_executions"] == 0
        hb = ac_on.send_matrix(ODD_A, dedup=False)
        ac_on.call("elemental", "gram", A=hb)
        assert engine.compile_log.stats()["bucketed_executions"] == 1
    finally:
        ac_off.stop()
        ac_on.stop()
        engine.shutdown()


# ---------------------------------------------------------------------------
# configure wire surface
# ---------------------------------------------------------------------------
def test_configure_echoes_bucketing_and_cache_dir(tmp_path):
    engine = fresh()
    ac = AlchemistContext(engine=engine)
    try:
        eff = ac.configure(bucketing=False)
        assert eff["bucketing"] is False
        eff = ac.configure(bucketing=True)
        assert eff["bucketing"] is True
        cache_dir = str(tmp_path / "cc")
        eff = ac.configure(cache_dir=cache_dir)
        assert eff["cache_dir"] == cache_dir
        assert engine.compile_cache_dir == cache_dir
    finally:
        ac.stop()
        engine.shutdown()


def test_configure_warmup_over_the_wire_returns_counts():
    engine = fresh()
    ac = AlchemistContext(engine=engine)
    try:
        eff = ac.configure(warmup=[32])
        w = eff["warmup"]
        assert w["backend"] == "jax"
        assert w["catalog"] >= len(BUCKETABLE_CASES)
        assert engine.compile_log.stats()["request_compiles"] == 0
    finally:
        ac.stop()
        engine.shutdown()


def test_configure_rejects_bad_options_without_mutating():
    engine = fresh()
    ac = AlchemistContext(engine=engine)
    try:
        with pytest.raises(AlchemistError, match="bucketing"):
            ac.configure(bucketing="yes")
        with pytest.raises(AlchemistError, match="warmup"):
            ac.configure(warmup=[0])
        with pytest.raises(AlchemistError, match="warmup"):
            ac.configure(warmup="now")
        with pytest.raises(AlchemistError, match="cache_dir"):
            ac.configure(cache_dir=7)
        sess = engine.session(ac.session)
        assert sess.bucketing is None        # nothing half-applied
        assert engine.compile_cache_dir is None
    finally:
        ac.stop()
        engine.shutdown()


def test_compile_stats_builtin_over_the_wire():
    engine = fresh(bucketing=True)
    ac = AlchemistContext(engine=engine)
    try:
        ha = ac.send_matrix(ODD_A, dedup=False)
        ac.call("elemental", "gram", A=ha)
        stats = ac.call("_engine", "compile_stats")
        assert stats["session"]["session"] == ac.session
        assert stats["session"]["compiles"] == 1
        assert stats["engine"]["bucketed_executions"] == 1
        assert "program_caches" in stats["engine"]
    finally:
        ac.stop()
        engine.shutdown()
