"""Tests for the §Perf framework features: microbatched accumulation,
mixed-precision cast, serve layout helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ShapeConfig, TrainConfig
from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.nn.core import init_params
from repro.train.loop import make_train_step
from repro.train.optim import adamw_init

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, mode="train")


def _setup(arch="stablelm-1.6b"):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = SyntheticLM(cfg, SHAPE, seed=0).batch(0)
    return model, params, batch


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation over microbatches must reproduce the full-batch
    mean loss and gradient. (Params after AdamW are compared loosely: the
    g/sqrt(v) normalization amplifies bf16-level gradient noise near zero,
    so the bound is ~2*lr per element.)"""
    model, params, batch = _setup()
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1, grad_clip=1e9)
    opt = adamw_init(params)
    full = make_train_step(model, tc, cast_params=False)
    micro = make_train_step(model, tc, microbatches=4, cast_params=False)
    p1, o1, m1 = jax.jit(full)(params, opt, batch)
    p2, o2, m2 = jax.jit(micro)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # mean gradients agree to activation-precision noise
    g1 = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    mb = jax.tree.map(lambda x: x.reshape(4, 1, *x.shape[1:]), batch)
    gs = [jax.grad(lambda p: model.loss(
        p, jax.tree.map(lambda x: x[i], mb))[0])(params) for i in range(4)]
    g2 = jax.tree.map(lambda *g: sum(g) / 4, *gs)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
    # params move together within the AdamW amplification bound
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert float(jnp.abs(a - b).max()) <= 2.5 * tc.learning_rate


def test_mixed_precision_cast_close_to_fp32():
    """bf16 cast-before-use must track the fp32 step loss closely."""
    model, params, batch = _setup()
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1)
    opt = adamw_init(params)
    _, _, m_cast = jax.jit(make_train_step(model, tc, cast_params=True))(
        params, opt, batch)
    _, _, m_fp32 = jax.jit(make_train_step(model, tc, cast_params=False))(
        params, opt, batch)
    assert abs(float(m_cast["loss"]) - float(m_fp32["loss"])) < 0.05


def test_train_step_still_learns_with_all_features():
    model, params, batch = _setup("qwen3-4b")
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, tc, microbatches=2))
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_analytic_decode_bytes_sane():
    from repro.common.config import SHAPES
    from repro.configs import get_config
    from repro.launch.roofline import (
        analytic_decode_bytes_per_chip,
        cache_bytes,
        param_count,
    )

    cfg = get_config("codeqwen1.5-7b")
    shape = SHAPES["decode_32k"]
    cb = cache_bytes(cfg, shape)
    # 2.2 TB global KV cache for 128 x 32k x 32 kv x 128 dh x 32 layers
    assert 2.0e12 < cb < 2.4e12
    per_chip = analytic_decode_bytes_per_chip(cfg, shape, 256)
    assert 8e9 < per_chip < 12e9          # ~9.5 GB/chip
    # SSM decode state is tiny by comparison
    rg = get_config("rwkv6-1.6b")
    assert cache_bytes(rg, SHAPES["long_500k"]) < 1e9
