"""Property-based tests (hypothesis) on the wire frame codec
(``core/wire.py``): every protocol message type round-trips through a
frame bit-exactly, chunk bodies survive for every supported dtype and
boundary size, and malformed / truncated / oversized / wrong-version
frames are rejected with the typed errors the server relies on for
per-connection fault containment.

Skipped cleanly when hypothesis is absent (it is declared in the
``test`` extra of pyproject.toml; CI installs it)."""
import socket
import struct

import msgpack
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; pip install -e '.[test]' to run these")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import protocol, wire  # noqa: E402
from repro.core.handles import MatrixHandle  # noqa: E402

# ---- strategies -------------------------------------------------------
_scalars = st.one_of(
    st.none(), st.booleans(), st.integers(-2**40, 2**40),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.text(max_size=12), st.binary(max_size=12))

_handles = st.builds(
    MatrixHandle,
    id=st.integers(1, 2**31),
    shape=st.tuples(st.integers(0, 999), st.integers(0, 99)),
    dtype=st.sampled_from(["float32", "float64", "int32"]),
    layout=st.sampled_from(["rowblock", "block2d", "replicated"]),
    name=st.one_of(st.none(), st.text(max_size=8)))

_deferred = st.builds(protocol.DeferredHandle,
                      task=st.integers(1, 2**31), key=st.text(max_size=8))

_args = st.dictionaries(
    st.text(min_size=1, max_size=8),
    st.one_of(_scalars, _handles, _deferred,
              st.lists(_scalars, max_size=3),
              st.dictionaries(st.text(min_size=1, max_size=4), _scalars,
                              max_size=3)),
    max_size=4)

_messages = st.one_of(
    st.builds(protocol.Handshake,
              action=st.sampled_from([protocol.CONNECT,
                                      protocol.DISCONNECT]),
              client=st.text(max_size=10), session=st.integers(0, 2**20)),
    st.builds(protocol.Command, library=st.text(min_size=1, max_size=10),
              routine=st.text(min_size=1, max_size=10), args=_args,
              session=st.integers(0, 2**20)),
    st.builds(protocol.TaskOp,
              action=st.sampled_from([protocol.POLL, protocol.WAIT]),
              task=st.integers(0, 2**31), session=st.integers(0, 2**20)),
    st.builds(protocol.Describe, library=st.text(max_size=10),
              session=st.integers(0, 2**20)),
    st.builds(protocol.Configure, session=st.integers(0, 2**20),
              options=st.dictionaries(
                  st.sampled_from(["backend", "fusion"]),
                  st.one_of(st.text(max_size=6), st.booleans()),
                  max_size=2)),
    st.builds(protocol.Result, values=_args,
              elapsed=st.floats(0, 1e3, allow_nan=False),
              error=st.text(max_size=20), session=st.integers(0, 2**20),
              task=st.integers(0, 2**31),
              state=st.sampled_from(["", "QUEUED", "DONE", "FAILED"]),
              wait_s=st.floats(0, 1e3, allow_nan=False),
              exec_s=st.floats(0, 1e3, allow_nan=False),
              cache_hit=st.booleans(),
              saved_s=st.floats(0, 1e3, allow_nan=False)))


# ---- typed message round trips ----------------------------------------
@settings(max_examples=120, deadline=None)
@given(msg=_messages)
def test_every_message_type_roundtrips_through_a_frame(msg):
    frame = wire.encode_message(msg)
    ftype, payload = wire.decode_frame(frame)
    assert wire.decode_message(ftype, payload) == msg


@settings(max_examples=60, deadline=None)
@given(msg=_messages)
def test_frames_survive_arbitrary_stream_slicing(msg):
    """A frame parsed off a buffered stream equals the buffer parse —
    framing is self-delimiting regardless of how TCP segments it."""
    import io

    frame = wire.encode_message(msg)
    got = wire.read_frame(io.BufferedReader(io.BytesIO(frame),
                                            buffer_size=1))
    assert got is not None
    assert wire.decode_message(*got) == msg


# ---- chunk bodies: dtype and boundary-size coverage -------------------
@settings(max_examples=80, deadline=None)
@given(
    dtype=st.sampled_from(["float32", "float64", "int32", "int64",
                           "uint8", "bool", "complex64"]),
    rows=st.integers(0, 33), cols=st.integers(0, 9),
    seed=st.integers(0, 2**31))
def test_chunk_bodies_roundtrip_every_dtype_and_size(dtype, rows, cols,
                                                     seed):
    rng = np.random.RandomState(seed % 2**32)
    a = (rng.randn(rows, cols) * 100).astype(dtype)
    frame = wire.encode_frame(
        wire.FRAME_UPLOAD_CHUNK,
        msgpack.packb({"array": wire.pack_ndarray(a)}))
    ftype, payload = wire.decode_frame(frame)
    back = wire.unpack_ndarray(msgpack.unpackb(payload)["array"])
    assert back.dtype == a.dtype and back.shape == a.shape
    np.testing.assert_array_equal(back, a)


@settings(max_examples=30, deadline=None)
@given(size=st.sampled_from([0, 1, 2, 11, 4096, 65536]))
def test_boundary_payload_sizes_roundtrip(size):
    payload = bytes(size)
    frame = wire.encode_frame(wire.FRAME_RESULT, payload)
    assert len(frame) == wire.HEADER_BYTES + size
    assert wire.decode_frame(frame) == (wire.FRAME_RESULT, payload)


def test_object_dtype_is_refused():
    a = np.array([object()], dtype=object)
    with pytest.raises((wire.WireError, TypeError)):
        wire.pack_ndarray(a)
    with pytest.raises(wire.WireError):
        wire.unpack_ndarray({"shape": [1], "dtype": "object",
                             "data": b"x"})


# ---- malformed frames are rejected with typed errors ------------------
@settings(max_examples=60, deadline=None)
@given(msg=_messages, data=st.data())
def test_truncated_frames_raise_typed(msg, data):
    """Cutting a frame anywhere — mid-header or mid-payload — is a
    TruncatedFrame, never a silent short read or a wrong parse."""
    frame = wire.encode_message(msg)
    cut = data.draw(st.integers(1, len(frame)))
    with pytest.raises(wire.TruncatedFrame):
        wire.decode_frame(frame[:len(frame) - cut])


def test_bad_magic_raises_typed():
    frame = b"NOPE" + wire.encode_frame(wire.FRAME_RESULT, b"")[4:]
    with pytest.raises(wire.BadMagic):
        wire.decode_frame(frame)


def test_oversized_frames_refused_both_directions(monkeypatch):
    # decode side: a hostile/corrupt declared length is refused from the
    # header alone, before any payload allocation
    header = struct.pack(">4sBBHI", wire.MAGIC, wire.WIRE_VERSION,
                         wire.FRAME_RESULT, 0, wire.MAX_FRAME_BYTES + 1)
    with pytest.raises(wire.FrameTooLarge):
        wire.decode_header(header)
    # encode side: refuse to emit what no peer would accept (cap shrunk
    # so the test doesn't allocate 256 MiB)
    monkeypatch.setattr(wire, "MAX_FRAME_BYTES", 1024)
    with pytest.raises(wire.FrameTooLarge):
        wire.encode_frame(wire.FRAME_RESULT, bytes(2048))


@settings(max_examples=40, deadline=None)
@given(ftype=st.integers(0, 255).filter(
    lambda t: t not in wire.FRAME_TYPES))
def test_unknown_frame_types_raise_typed(ftype):
    header = struct.pack(">4sBBHI", wire.MAGIC, wire.WIRE_VERSION,
                         ftype, 0, 0)
    with pytest.raises(wire.UnknownFrameType):
        wire.decode_header(header)
    with pytest.raises(wire.UnknownFrameType):
        wire.encode_frame(ftype, b"")


@settings(max_examples=40, deadline=None)
@given(version=st.integers(0, 255).filter(
    lambda v: v != wire.WIRE_VERSION))
def test_version_mismatch_raises_typed(version):
    header = struct.pack(">4sBBHI", wire.MAGIC, version,
                         wire.FRAME_HANDSHAKE, 0, 0)
    with pytest.raises(wire.VersionMismatch):
        wire.decode_header(header)


def test_error_frames_rebuild_their_typed_fault():
    for exc in (wire.BadMagic("m"), wire.VersionMismatch("v"),
                wire.FrameTooLarge("l"), wire.UnknownFrameType("t"),
                wire.TruncatedFrame("c"), wire.RemoteFault("f")):
        back = wire.decode_error(wire.encode_error(exc))
        assert type(back) is type(exc)
        assert str(exc) in str(back)


# ---- version-mismatch handshake refusal, live against a server --------
def test_version_mismatch_handshake_is_refused_by_server():
    """A client speaking a different wire version is told so in a typed
    ERROR frame and hung up on — before any engine state is touched."""
    from repro.core.server import AlchemistServer

    with AlchemistServer() as srv:
        sessions_before = len(srv.engine.sessions())
        sock = socket.create_connection((srv.host, srv.port), timeout=30)
        try:
            hs = protocol.encode_handshake(
                protocol.Handshake(action=protocol.CONNECT, client="v2"))
            sock.sendall(wire.encode_frame(wire.FRAME_HANDSHAKE, hs,
                                           version=wire.WIRE_VERSION + 1))
            rfile = sock.makefile("rb")
            got = wire.read_frame(rfile)
            assert got is not None
            ftype, payload = got
            assert ftype == wire.FRAME_ERROR
            with pytest.raises(wire.VersionMismatch):
                raise wire.decode_error(payload)
            assert rfile.read(1) == b""        # server hung up
        finally:
            sock.close()
        assert len(srv.engine.sessions()) == sessions_before