"""Property-based tests (hypothesis) on system invariants.

Skipped cleanly when hypothesis is absent (it is declared in the
``test`` extra of pyproject.toml; CI installs it)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; pip install -e '.[test]' to run these")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.common.config import ModelConfig
from repro.core import AlchemistContext
from repro.core.costmodel import socket_transfer_seconds
from repro.core.libraries import elemental, skylark
from repro.core.protocol import (
    Command,
    decode_command,
    encode_command,
)
from repro.core.handles import MatrixHandle
from repro.train.loss import softmax_cross_entropy

_AC = None


def _ac():
    global _AC
    if _AC is None:
        _AC = AlchemistContext(num_workers=1)
        _AC.register_library("elemental", elemental)
        _AC.register_library("skylark", skylark)
    return _AC


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 120), d=st.integers(2, 12),
       c=st.integers(1, 3), seed=st.integers(0, 100))
def test_cg_solves_any_ridge_system(n, d, c, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, d)
    y = rng.randn(n, c)
    lam = 1e-2
    ac = _ac()
    res = ac.call("skylark", "cg_solve", X=ac.send_matrix(x),
                  Y=ac.send_matrix(y), lam=lam, max_iters=5 * d, tol=1e-12)
    w = ac.wrap(res["W"]).to_numpy()
    want = np.linalg.solve(x.T @ x + n * lam * np.eye(d), x.T @ y)
    np.testing.assert_allclose(w, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(8, 64), cols=st.integers(2, 16),
       seed=st.integers(0, 50))
def test_transfer_roundtrip_preserves_data(rows, cols, seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(rows, cols)
    ac = _ac()
    al = ac.send_matrix(a)
    back = al.to_numpy()
    np.testing.assert_allclose(back, a, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(2, 6), st.integers(2, 6),
       st.text(max_size=10), st.integers(0, 3))
def test_protocol_roundtrip_any_args(hid, r, c, name, session):
    h = MatrixHandle(id=hid, shape=(r, c), dtype="float32", name=name or None)
    cmd = Command("lib", "fn", {"A": h, "s": name, "x": 1.5, "flag": True,
                                "nest": {"k": [1, 2, h]}}, session=session)
    back = decode_command(encode_command(cmd))
    assert back == cmd


@settings(max_examples=25, deadline=None)
@given(nbytes=st.integers(1, 10**13), a=st.integers(1, 64),
       b=st.integers(1, 64))
def test_transfer_model_monotone(nbytes, a, b):
    """More bytes never transfer faster; more (balanced) procs never slower."""
    t = socket_transfer_seconds(nbytes, a, b)
    assert t >= 0
    assert socket_transfer_seconds(nbytes * 2, a, b) >= t
    assert socket_transfer_seconds(nbytes, a + 1, b + 1) <= t + 1e-9


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 8), v=st.integers(2, 30),
       seed=st.integers(0, 99))
def test_cross_entropy_matches_naive(b, s, v, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (b, s, v))
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0, v)
    got = float(softmax_cross_entropy(logits, labels))
    probs = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.mean(jnp.take_along_axis(
        probs, labels[..., None], axis=-1)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_cross_entropy_ignores_masked_labels(seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (2, 6, 11))
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 6), 0, 11)
    masked = labels.at[:, -2:].set(-1)
    got = float(softmax_cross_entropy(logits, masked))
    want = float(softmax_cross_entropy(logits[:, :-2], labels[:, :-2]))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(40, 100), d=st.integers(6, 20), k=st.integers(1, 4),
       seed=st.integers(0, 20))
def test_truncated_svd_is_best_rank_k(n, d, k, seed):
    """Eckart-Young: residual of our rank-k factors ~ sigma_{k+1}."""
    rng = np.random.RandomState(seed)
    x = rng.randn(n, d)
    ac = _ac()
    res = ac.call("elemental", "truncated_svd", A=ac.send_matrix(x), k=k)
    u = ac.wrap(res["U"]).to_numpy()
    s = ac.wrap(res["S"]).to_numpy().ravel()
    v = ac.wrap(res["V"]).to_numpy()
    resid = np.linalg.norm(x - u @ np.diag(s) @ v.T, 2)
    svals = np.linalg.svd(x, compute_uv=False)
    assert resid <= svals[k] * (1 + 1e-3) + 1e-6
