"""Layer-level invariants: decode-with-cache == full forward, chunked ==
sequential scan, absorbed MLA == expanded MLA, MoE reference path sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig, MoEConfig
from repro.nn import core as nncore
from repro.nn import attention as attn
from repro.nn.mla import MLACache, apply_mla, mla_spec
from repro.nn.moe import moe_apply, moe_spec
from repro.nn.rglru import RGLRUCache, apply_rglru, rglru_spec
from repro.nn.rwkv import RWKVCache, apply_rwkv, rwkv_spec

B, S, D = 2, 16, 64
KEY = jax.random.PRNGKey(0)
X = jax.random.normal(KEY, (B, S, D), jnp.float32)
POS = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def _cfg(**kw):
    base = dict(name="t", num_layers=2, d_model=D, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=100)
    base.update(kw)
    return ModelConfig(**base)


def _decode_match(apply_fn, make_cache, tol=2e-5):
    """Run full forward; then prefill S-1 + decode 1; compare last position."""
    full = apply_fn(X, cache=None)
    cache = make_cache()
    _, cache_p = apply_fn(X[:, : S - 1], cache=cache)
    out_d, _ = apply_fn(X[:, S - 1 :], cache=cache_p, decode=True)
    np.testing.assert_allclose(np.asarray(out_d[:, 0]),
                               np.asarray(full[0][:, -1]), rtol=tol, atol=tol)


def test_attention_decode_matches_full():
    cfg = _cfg(qk_norm=True)
    params = nncore.init_params(attn.attention_spec(cfg), KEY)

    def apply_fn(x, cache=None, decode=False):
        pos = POS[:, : x.shape[1]] if not decode else POS[:, S - 1 :]
        idx = jnp.int32(S - 1) if decode else None
        return attn.apply_attention(params, x, pos, cfg, cache=cache,
                                    cache_index=idx,
                                    compute_dtype=jnp.float32)

    def make_cache():
        return attn.KVCache(k=jnp.zeros((B, S, 2, 16)),
                            v=jnp.zeros((B, S, 2, 16)))

    _decode_match(apply_fn, make_cache)


def test_local_attention_ring_cache_matches_full():
    w = 8
    cfg = _cfg(sliding_window=w)
    params = nncore.init_params(attn.attention_spec(cfg), KEY)

    def apply_fn(x, cache=None, decode=False):
        pos = POS[:, : x.shape[1]] if not decode else POS[:, S - 1 :]
        idx = jnp.int32(S - 1) if decode else None
        return attn.apply_attention(params, x, pos, cfg, window=w,
                                    cache=cache, cache_index=idx,
                                    compute_dtype=jnp.float32)

    def make_cache():
        return attn.KVCache(k=jnp.zeros((B, w, 2, 16)),
                            v=jnp.zeros((B, w, 2, 16)))

    _decode_match(apply_fn, make_cache)


def test_chunked_attention_matches_unchunked():
    cfg = _cfg()
    params = nncore.init_params(attn.attention_spec(cfg), KEY)
    s2 = 64
    x = jax.random.normal(KEY, (B, s2, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s2, dtype=jnp.int32)[None], (B, s2))
    q = jnp.einsum("bsd,dhk->bshk", x, params["q"]["w"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["k"]["w"][:, :2])
    v = jnp.einsum("bsd,dhk->bshk", x, params["v"]["w"][:, :2])
    o1 = attn.multihead_attention(q, k, v, pos, pos, q_chunk=16)
    o2 = attn.multihead_attention(q, k, v, pos, pos, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_rglru_decode_matches_full():
    cfg = _cfg(lru_width=D)
    params = nncore.init_params(rglru_spec(cfg), KEY)

    def apply_fn(x, cache=None, decode=False):
        return apply_rglru(params, x, cfg, cache=cache,
                           compute_dtype=jnp.float32)

    def make_cache():
        return RGLRUCache(h=jnp.zeros((B, D)), conv=jnp.zeros((B, 3, D)))

    _decode_match(apply_fn, make_cache)


def test_rwkv_decode_matches_full():
    cfg = _cfg(rwkv_head_dim=16)
    params = nncore.init_params(rwkv_spec(cfg), KEY)

    def apply_fn(x, cache=None, decode=False):
        return apply_rwkv(params, x, cfg, cache=cache,
                          compute_dtype=jnp.float32)

    def make_cache():
        return RWKVCache(state=jnp.zeros((B, 4, 16, 16)),
                         last=jnp.zeros((B, D)), last_cm=jnp.zeros((B, D)))

    _decode_match(apply_fn, make_cache, tol=1e-4)


def test_rwkv_chunked_matches_scan():
    cfg = _cfg(rwkv_head_dim=16)
    params = nncore.init_params(rwkv_spec(cfg), KEY)
    s2 = 256
    x = jax.random.normal(KEY, (B, s2, D), jnp.float32)
    y_chunked, _ = apply_rwkv(params, x, cfg, compute_dtype=jnp.float32)
    y_scan, _ = apply_rwkv(params, x[:, : s2 - 1], cfg,
                           compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_chunked[:, : s2 - 1]),
                               np.asarray(y_scan), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("q_lora", [0, 48])
def test_mla_absorbed_decode_matches_expanded(q_lora):
    cfg = _cfg(num_kv_heads=4, kv_lora_rank=32, q_lora_rank=q_lora,
               rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
    params = nncore.init_params(mla_spec(cfg), KEY)

    def apply_fn(x, cache=None, decode=False):
        pos = POS[:, : x.shape[1]] if not decode else POS[:, S - 1 :]
        idx = jnp.int32(S - 1) if decode else None
        return apply_mla(params, x, pos, cfg, cache=cache, cache_index=idx,
                         compute_dtype=jnp.float32)

    def make_cache():
        return MLACache(c_kv=jnp.zeros((B, S, 32)),
                        k_rope=jnp.zeros((B, S, 8)))

    _decode_match(apply_fn, make_cache)


def test_moe_routes_and_balances():
    cfg = _cfg(moe=MoEConfig(num_experts=8, num_shared_experts=1, top_k=2,
                             expert_ff=32))
    params = nncore.init_params(moe_spec(cfg), KEY)
    y, aux = moe_apply(params, X, cfg, compute_dtype=jnp.float32)
    assert y.shape == X.shape
    assert not bool(jnp.isnan(y).any())
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and uniform-ish routing, output magnitude
    should be comparable to a dense MLP's (no catastrophic drop)."""
    cfg = _cfg(moe=MoEConfig(num_experts=4, num_shared_experts=0, top_k=2,
                             expert_ff=32, capacity_factor=2.0))
    params = nncore.init_params(moe_spec(cfg), KEY)
    y, _ = moe_apply(params, X, cfg, compute_dtype=jnp.float32)
    assert float(jnp.mean(jnp.abs(y))) > 1e-4
