"""Fault injection against the TCP engine server (``core/server.py``):
clients that vanish mid-upload or mid-task, stalled readers, framing
offenders, server shutdown under load, and reconnect semantics. Each
scenario asserts the engine's state afterwards — sessions reclaimed,
in-flight tasks drained, staged uploads discarded, other tenants
untouched — because fault containment is the server's whole job.

Also home to the cross-bridge accounting regression: endpoint_counts
count *logical* calls identically on both bridges, while the physical
frame/byte truth lives in the wire logs and per-record ``wire_nbytes``.
"""
import socket
import time

import msgpack
import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine
from repro.core import protocol, wire
from repro.core.engine import SYSTEM_SESSION, make_engine_mesh
from repro.core.libraries import elemental
from repro.core.scheduler import DONE, QUEUED, RUNNING
from repro.core.server import AlchemistServer

RNG = np.random.RandomState(11)


def _wait_until(pred, timeout=15.0, what="condition"):
    """Poll for an asynchronous cleanup to land (teardown runs on the
    connection's handler thread, not the test thread)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {what}")


def _session_ids(engine):
    return {s.id for s in engine.sessions()}


@pytest.fixture()
def engine():
    eng = AlchemistEngine(make_engine_mesh(1), scheduler_workers=4)
    yield eng
    eng.shutdown()


@pytest.fixture()
def server(engine):
    with AlchemistServer(engine=engine) as srv:
        yield srv


def _connect_bridge(server):
    """A raw SocketBridge with an open session — no context on top, so
    tests can speak half a protocol exchange and then misbehave."""
    bridge = wire.SocketBridge(server.address)
    reply = protocol.decode_result(bridge.handshake(
        protocol.encode_handshake(protocol.Handshake(
            action=protocol.CONNECT, client="fault-test"))))
    return bridge, reply.values["session"]


# =====================================================================
# vanish mid-chunked-upload
# =====================================================================
def test_disconnect_mid_upload_discards_staged_data_and_session(
        engine, server):
    """A client that dies between BEGIN and COMMIT leaves nothing
    behind: no staged pieces, no handle, no session."""
    resident_before = engine.resident_bytes()
    sessions_before = _session_ids(engine)

    bridge, sid = _connect_bridge(server)
    assert sid in _session_ids(engine)

    begin = msgpack.packb({"shape": [64, 8], "dtype": "float32",
                           "session": sid, "name": "doomed",
                           "num_chunks": 4, "single": False})
    with bridge._lock:
        bridge._send("upload", wire.FRAME_UPLOAD_BEGIN, begin)
        _, reply = bridge._recv("upload")
    uid = protocol.decode_result(reply).values["upload"]
    chunk = np.ones((16, 8), np.float32)
    bridge._send("upload", wire.FRAME_UPLOAD_CHUNK, msgpack.packb(
        {"upload": uid, "seq": 0, "array": wire.pack_ndarray(chunk)}))

    bridge.close()                          # abrupt: no COMMIT, no bye

    _wait_until(lambda: sid not in _session_ids(engine),
                what="session reclaim after mid-upload disconnect")
    _wait_until(lambda: len(server._conns) == 0,
                what="connection teardown")
    assert _session_ids(engine) == sessions_before
    assert engine.resident_bytes() == resident_before


def test_disconnect_drains_in_flight_tasks(engine, server):
    """Vanishing with tasks QUEUED/RUNNING runs the engine's normal
    teardown: the tasks drain to a terminal state, then the session's
    handles are reclaimed — nothing is left RUNNING forever."""
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.4: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    ctx = AlchemistContext(address=server.address)
    sid = ctx.session
    fut = ctx.call_async("slow", "nap")
    assert fut.state() in (QUEUED, RUNNING, DONE)

    ctx.engine.close()                      # hang up without DISCONNECT

    _wait_until(lambda: sid not in _session_ids(engine),
                what="session reclaim after mid-task disconnect")
    # drained, not killed: the nap reached DONE before the session was
    # reclaimed (disconnect forgets the session's tasks from the live
    # scheduler table, so assert on the engine's permanent task log)
    counts = engine.scheduler.counts()
    assert counts[QUEUED] == 0 and counts[RUNNING] == 0
    summary = engine.task_log.session_summary(sid)
    assert summary["tasks"] >= 1 and summary["failed"] == 0


# =====================================================================
# tenant isolation
# =====================================================================
def test_stalled_reader_does_not_block_other_tenants(engine, server):
    """One connection parked mid-frame-header must not stall dispatch
    for anyone else — handler threads are per-connection."""
    engine.load_library("elemental", elemental)
    staller = socket.create_connection((server.host, server.port),
                                       timeout=30)
    try:
        frame = wire.encode_frame(
            wire.FRAME_HANDSHAKE, protocol.encode_handshake(
                protocol.Handshake(action=protocol.CONNECT)))
        staller.sendall(frame[:6])          # half a header, then silence

        with AlchemistContext(address=server.address) as ctx:
            x = RNG.randn(48, 6).astype(np.float32)
            al = ctx.send_matrix(x, chunk_rows=16)
            out = ctx.call("elemental", "gram", A=al.handle)
            got = ctx.fetch(out["G"]).collect()
            np.testing.assert_allclose(got, x.T @ x, rtol=1e-4,
                                       atol=1e-4)
    finally:
        staller.close()
    _wait_until(lambda: len(server._conns) == 0,
                what="stalled connection teardown")


def test_framing_fault_hangs_up_only_the_offender(engine, server):
    """Garbage bytes earn that connection a typed ERROR frame and a
    hangup; a well-behaved tenant sharing the server never notices."""
    ctx = AlchemistContext(address=server.address)
    try:
        offender = socket.create_connection((server.host, server.port),
                                            timeout=30)
        try:
            offender.sendall(b"X" * wire.HEADER_BYTES)
            rfile = offender.makefile("rb")
            got = wire.read_frame(rfile)
            assert got is not None and got[0] == wire.FRAME_ERROR
            assert isinstance(wire.decode_error(got[1]), wire.BadMagic)
            assert rfile.read(1) == b""     # offender is hung up on
        finally:
            offender.close()

        # the innocent tenant's connection still works end to end
        x = RNG.randn(12, 3).astype(np.float32)
        al = ctx.send_matrix(x)
        back = ctx.fetch(al.handle).collect()
        np.testing.assert_array_equal(back, x)
    finally:
        ctx.stop()


# =====================================================================
# shutdown and reconnect
# =====================================================================
def test_server_stop_drains_in_flight_tasks(engine):
    """``stop()`` hangs up every client; each handler's teardown waits
    for that session's tasks before reclaiming — shutdown is a drain,
    not an abort."""
    class _Slow:
        ROUTINES = {"nap": lambda eng, s=0.4: time.sleep(s) or {"ok": 1}}

    engine.load_library("slow", _Slow)
    srv = AlchemistServer(engine=engine).start()
    ctx = AlchemistContext(address=srv.address)
    sid = ctx.session
    ctx.call_async("slow", "nap")

    srv.stop()                              # engine is ours, stays up

    counts = engine.scheduler.counts()
    assert counts[QUEUED] == 0 and counts[RUNNING] == 0
    summary = engine.task_log.session_summary(sid)
    assert summary["tasks"] >= 1 and summary["failed"] == 0
    assert _session_ids(engine) == {SYSTEM_SESSION}
    # the engine survives a front-end stop and is immediately reusable
    s2 = engine.connect(client="after-stop")
    engine.disconnect(s2.id)


def test_reconnect_gets_fresh_session_namespace(engine, server):
    """A reconnecting client is a new tenant: new session id, and the
    old session's handles are gone — freed on disconnect, not parked."""
    ctx1 = AlchemistContext(address=server.address)
    sid1 = ctx1.session
    x = RNG.randn(20, 4).astype(np.float32)
    old_handle = ctx1.send_matrix(x, name="mine").handle
    ctx1.engine.close()                     # vanish, no DISCONNECT

    _wait_until(lambda: sid1 not in _session_ids(engine),
                what="first session reclaim")

    with AlchemistContext(address=server.address) as ctx2:
        assert ctx2.session != sid1
        with pytest.raises(KeyError):
            ctx2.fetch(old_handle)


# =====================================================================
# QoS backpressure faults (admission control + THROTTLE frames)
# =====================================================================
def test_vanish_while_throttled_reclaims_reservations():
    """A tenant that reserves upload quota, gets throttled on a second
    upload, then vanishes must leak nothing: its open reservation is
    reclaimed by disconnect and the full quota is available again."""
    eng = AlchemistEngine(make_engine_mesh(1), qos=True,
                          qos_quotas={"max_inflight_bytes": 4096})
    try:
        with AlchemistServer(engine=eng) as srv:
            bridge, sid = _connect_bridge(srv)
            begin = msgpack.packb({"shape": [64, 8], "dtype": "float32",
                                   "session": sid, "name": None,
                                   "num_chunks": 4, "single": False})
            with bridge._lock:
                bridge._send("upload", wire.FRAME_UPLOAD_BEGIN, begin)
                ftype, reply = bridge._recv("upload")
            assert ftype == wire.FRAME_RESULT
            assert not protocol.decode_result(reply).error
            assert eng.admission.inflight_bytes(sid) == 64 * 8 * 4

            # a second BEGIN that would overflow the quota earns a
            # THROTTLE frame with a retry hint — and stages nothing
            big = msgpack.packb({"shape": [512, 8], "dtype": "float32",
                                 "session": sid, "name": None,
                                 "num_chunks": 8, "single": False})
            with bridge._lock:
                bridge._send("upload", wire.FRAME_UPLOAD_BEGIN, big)
                ftype, reply = bridge._recv("upload")
            assert ftype == wire.FRAME_THROTTLE
            res = protocol.decode_result(reply)
            assert res.error.startswith("AlchemistBusyError")
            assert res.retry_after_s > 0
            assert eng.admission.inflight_bytes(sid) == 64 * 8 * 4

            bridge.close()              # vanish: BEGIN never committed

            _wait_until(lambda: sid not in _session_ids(eng),
                        what="session reclaim after throttled vanish")
            _wait_until(lambda: eng.admission.inflight_bytes(sid) == 0,
                        what="upload reservation reclaim")

            # the quota is whole again for the next tenant
            bridge2, sid2 = _connect_bridge(srv)
            with bridge2._lock:
                bridge2._send("upload", wire.FRAME_UPLOAD_BEGIN,
                              msgpack.packb(
                                  {"shape": [128, 8], "dtype": "float32",
                                   "session": sid2, "name": None,
                                   "num_chunks": 4, "single": False}))
                ftype, reply = bridge2._recv("upload")
            assert ftype == wire.FRAME_RESULT
            assert not protocol.decode_result(reply).error
            bridge2.close()
    finally:
        eng.shutdown()


def test_throttle_frame_from_client_is_refused(engine, server):
    """THROTTLE is a reply-role frame: a client sending one as a request
    gets the typed unknown-request ERROR, and nobody else notices."""
    ctx = AlchemistContext(address=server.address)
    try:
        offender = socket.create_connection((server.host, server.port),
                                            timeout=30)
        try:
            offender.sendall(wire.encode_frame(wire.FRAME_THROTTLE, b""))
            rfile = offender.makefile("rb")
            got = wire.read_frame(rfile)
            assert got is not None and got[0] == wire.FRAME_ERROR
            err = wire.decode_error(got[1])
            assert isinstance(err, wire.UnknownFrameType)
            assert "not a request" in str(err)
        finally:
            offender.close()

        # the innocent tenant's connection still works end to end
        x = RNG.randn(12, 3).astype(np.float32)
        al = ctx.send_matrix(x)
        back = ctx.fetch(al.handle).collect()
        np.testing.assert_array_equal(back, x)
    finally:
        ctx.stop()


# =====================================================================
# accounting: logical counts vs physical frames (satellite regression)
# =====================================================================
def _workload(ctx):
    x = np.arange(40 * 6, dtype=np.float32).reshape(40, 6)
    al = ctx.send_matrix(x, chunk_rows=16)
    out = ctx.call("elemental", "gram", A=al.handle)
    ctx.fetch(out["G"])
    ctx.send_matrix(x, chunk_rows=16)       # warm: dedup short-circuit
    return al


def test_endpoint_counts_stay_logical_on_both_bridges():
    """The same workload produces byte-identical protocol traffic on
    both bridges, so the engine's endpoint_counts — logical calls — must
    match exactly; the socket's extra physical cost shows up only in the
    wire logs and per-record wire_nbytes."""
    eng_mem = AlchemistEngine(make_engine_mesh(1))
    eng_mem.load_library("elemental", elemental)
    with AlchemistContext(engine=eng_mem) as ctx:
        al_mem = _workload(ctx)
        counts_mem = dict(eng_mem.endpoint_counts)
        # in-memory transfers never touch a socket: wire_nbytes stays 0
        assert al_mem.last_transfer.wire_nbytes == 0
    eng_mem.shutdown()

    eng_sock = AlchemistEngine(make_engine_mesh(1))
    eng_sock.load_library("elemental", elemental)
    with AlchemistServer(engine=eng_sock) as srv:
        with AlchemistContext(address=srv.address) as ctx:
            upload_frames = srv.wire_log.stat("upload").frames_in
            al_sock = _workload(ctx)
            counts_sock = dict(eng_sock.endpoint_counts)

            # logical crossings are identical across transports
            assert counts_sock == counts_mem

            # physical truth: the chunked upload cost more bytes on the
            # wire than the matrix holds (framing + headers), and every
            # touched endpoint has measured traffic on both ends
            rec = al_sock.last_transfer
            assert rec.wire_nbytes > rec.nbytes > 0
            for endpoint in ("handshake", "submit", "upload", "fetch"):
                assert srv.wire_log.stat(endpoint).frames_in > 0
                assert ctx.engine.wire_log.stat(endpoint).frames_out > 0

            # warm re-upload deduped: its one crossing was the
            # alias-lookup probe, not upload frames
            warm = eng_sock.transfer_log.records[-1]
            assert warm.dedup and warm.nbytes == 0
            assert 0 < warm.wire_nbytes < rec.nbytes
            frames_now = srv.wire_log.stat("upload").frames_in
            cold_frames = 2 + 3             # BEGIN/COMMIT + 3 chunks
            assert frames_now - upload_frames == cold_frames
    eng_sock.shutdown()
