"""JIT chain fusion: burst-submitted dependency chains execute as ONE
fused backend program — correctness vs eager execution, scheduler-hazard
interaction, failure semantics, cache-fingerprint identity fused vs
unfused, and the cost-model accounting (`TaskLog.stats()`)."""
import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine
from repro.core.context import AlchemistError
from repro.core.engine import make_engine_mesh
from repro.core.libraries import elemental

RNG = np.random.RandomState(3)
A = (RNG.randn(16, 16) / 4.0).astype(np.float32)


def fresh(cache_entries=0, fuse_chains=True, **ctx_kw):
    engine = AlchemistEngine(make_engine_mesh(1),
                             cache_entries=cache_entries,
                             fuse_chains=fuse_chains)
    engine.load_library("elemental", elemental)
    ac = AlchemistContext(engine=engine, **ctx_kw)
    return engine, ac


def burst_chain(ac, al, stages):
    """Submit a multiply chain in one burst (scheduler paused so the
    whole chain lands in the table before dispatch — deterministic
    claiming), force, and return the proxies."""
    el = ac.library("elemental")
    ac.engine.scheduler.pause()
    xs = [al]
    for _ in range(stages):
        xs.append(el.multiply(A=xs[-1], B=al))
    ac.engine.scheduler.resume()
    xs[-1].result()
    return xs


def chain_power(a, stages):
    want = a
    for _ in range(stages):
        want = want @ a
    return want


# ---------------------------------------------------------------------------
# the headline: one dispatch for the whole chain
# ---------------------------------------------------------------------------
def test_burst_chain_fuses_into_one_dispatched_task():
    engine, ac = fresh()
    try:
        al = ac.send_matrix(A)
        before = engine.task_log.stats()
        xs = burst_chain(ac, al, 4)
        stats = engine.task_log.stats()
        assert stats["dispatched"] - before["dispatched"] == 1
        assert stats["absorbed"] - before["absorbed"] == 3
        assert stats["fused_tasks"] == 1 and stats["fused_ops"] == 4
        np.testing.assert_allclose(xs[-1].to_numpy(), chain_power(A, 4),
                                   rtol=1e-3, atol=1e-5)
    finally:
        ac.stop()
        engine.shutdown()


def test_fused_matches_eager_per_op_results():
    engine_f, ac_f = fresh()
    engine_e, ac_e = fresh()
    try:
        out_f = burst_chain(ac_f, ac_f.send_matrix(A), 5)[-1].to_numpy()
        assert engine_f.task_log.stats()["fused_tasks"] == 1
        # eager: one blocking call per op — never fuses
        al = ac_e.send_matrix(A)
        x = al
        for _ in range(5):
            x = ac_e.wrap(ac_e.call("elemental", "multiply",
                                    A=x, B=al)["C"])
        assert engine_e.task_log.stats()["fused_tasks"] == 0
        np.testing.assert_allclose(out_f, x.to_numpy(), rtol=1e-4,
                                   atol=1e-5)
    finally:
        ac_f.stop()
        engine_f.shutdown()
        ac_e.stop()
        engine_e.shutdown()


def test_intermediate_outputs_of_fused_chain_are_real():
    """Absorbed commands still deliver: every intermediate proxy forces
    to the correct value (clients may hold any of them)."""
    engine, ac = fresh()
    try:
        xs = burst_chain(ac, ac.send_matrix(A), 3)
        for i, x in enumerate(xs[1:], start=1):
            np.testing.assert_allclose(x.to_numpy(), chain_power(A, i),
                                       rtol=1e-3, atol=1e-5)
            assert x.future.state() == "DONE"
    finally:
        ac.stop()
        engine.shutdown()


def test_mixed_op_chain_fuses():
    engine, ac = fresh()
    try:
        el = ac.library("elemental")
        al = ac.send_matrix(A)
        engine.scheduler.pause()
        c1 = el.multiply(A=al, B=al)
        c2 = el.transpose(A=c1)
        c3 = el.add(A=c2, B=al)
        engine.scheduler.resume()
        got = c3.to_numpy()
        stats = engine.task_log.stats()
        assert stats["fused_tasks"] == 1 and stats["fused_ops"] == 3
        np.testing.assert_allclose(got, (A @ A).T + A, rtol=1e-4,
                                   atol=1e-5)
    finally:
        ac.stop()
        engine.shutdown()


def test_fusion_toggles():
    # per-session opt-out
    engine, ac = fresh(fusion=False)
    try:
        burst_chain(ac, ac.send_matrix(A), 3)
        assert engine.task_log.stats()["fused_tasks"] == 0
    finally:
        ac.stop()
        engine.shutdown()
    # engine-wide kill switch
    engine, ac = fresh(fuse_chains=False)
    try:
        burst_chain(ac, ac.send_matrix(A), 3)
        assert engine.task_log.stats()["fused_tasks"] == 0
    finally:
        ac.stop()
        engine.shutdown()
    # reference backend never fuses (no fused program to build)
    engine, ac = fresh(backend="reference")
    try:
        xs = burst_chain(ac, ac.send_matrix(A), 3)
        assert engine.task_log.stats()["fused_tasks"] == 0
        np.testing.assert_allclose(xs[-1].to_numpy(), chain_power(A, 3),
                                   rtol=1e-3, atol=1e-5)
    finally:
        ac.stop()
        engine.shutdown()


# ---------------------------------------------------------------------------
# scheduler hazards: fusion must never reorder against a write
# ---------------------------------------------------------------------------
def test_interleaved_write_hazard_breaks_claim_and_keeps_order():
    """A write on the chain's leaf between two chain submissions must
    execute between them, fused or not: the writer's hazard edge stops
    the claim, and the results match eager per-op execution."""
    def scale(eng, M, factor: float = 2.0):
        import jax.numpy as jnp
        eng.overwrite(M, jnp.asarray(eng.get(M)) * factor)
        return {"M": M}
    scale.writes = ("M",)

    class _W:
        ROUTINES = {"scale": scale}

    engine, ac = fresh()
    engine.load_library("w", _W)
    try:
        el = ac.library("elemental")
        al = ac.send_matrix(A)
        engine.scheduler.pause()
        m1 = el.multiply(A=al, B=al)          # reads old leaf
        f_scale = ac.call_async("w", "scale", M=al, factor=2.0)
        m2 = el.multiply(A=m1, B=al)          # reads *scaled* leaf
        engine.scheduler.resume()
        got1, got2 = m1.to_numpy(), m2.to_numpy()
        f_scale.result()
        # eager semantics: m1 = A@A, then leaf *= 2, m2 = (A@A) @ (2A)
        np.testing.assert_allclose(got1, A @ A, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(got2, (A @ A) @ (2.0 * A),
                                   rtol=1e-4, atol=1e-4)
        # the write sat between the two ops, so nothing fused across it
        assert engine.task_log.stats()["fused_tasks"] == 0
    finally:
        ac.stop()
        engine.shutdown()


def test_other_sessions_overwrite_of_shared_store_is_isolated():
    """The cross-session variant: another tenant overwrites its alias of
    the chain's leaf store (minted by upload dedup) mid-burst. Copy-on-
    write isolates the chain either way — results equal eager."""
    def zero(eng, M):
        import jax.numpy as jnp
        eng.overwrite(M, jnp.zeros(tuple(M.shape), jnp.float32))
        return {"M": M}
    zero.writes = ("M",)

    class _W:
        ROUTINES = {"zero": zero}

    engine, ac_a = fresh()
    engine.load_library("w", _W)
    ac_b = AlchemistContext(engine=engine)
    try:
        al_a = ac_a.send_matrix(A)
        al_b = ac_b.send_matrix(A)        # dedup: alias of the same store
        engine.scheduler.pause()
        el = ac_a.library("elemental")
        x = el.multiply(A=al_a, B=al_a)
        y = el.multiply(A=x, B=al_a)
        fz = ac_b.call_async("w", "zero", M=al_b)
        engine.scheduler.resume()
        np.testing.assert_allclose(y.to_numpy(), chain_power(A, 2),
                                   rtol=1e-4, atol=1e-5)
        fz.result()
        np.testing.assert_allclose(
            np.asarray(engine.get(al_b.handle, session=ac_b.session)),
            np.zeros_like(A))
        np.testing.assert_allclose(
            np.asarray(engine.get(al_a.handle, session=ac_a.session)),
            A, rtol=1e-6)
    finally:
        ac_b.stop()
        ac_a.stop()
        engine.shutdown()


# ---------------------------------------------------------------------------
# failure semantics
# ---------------------------------------------------------------------------
def test_fused_chain_failure_matches_eager_semantics():
    """A mid-chain shape error: steps before it succeed, the broken step
    fails with the routine's error, later steps fail as upstream
    casualties — exactly like unfused dispatch."""
    engine, ac = fresh()
    try:
        rect = RNG.randn(16, 8).astype(np.float32)
        al = ac.send_matrix(rect)
        el = ac.library("elemental")
        engine.scheduler.pause()
        t = el.transpose(A=al)               # (8, 16) — fine
        bad = el.multiply(A=t, B=t)          # (8,16) @ (8,16) — breaks
        worse = el.multiply(A=bad, B=bad)    # upstream casualty
        engine.scheduler.resume()
        np.testing.assert_allclose(t.to_numpy(), rect.T, rtol=1e-6)
        with pytest.raises(AlchemistError):
            bad.result()
        with pytest.raises(AlchemistError, match="upstream"):
            worse.result()
    finally:
        ac.stop()
        engine.shutdown()


def test_fused_delivery_failure_never_strands_claimed_tasks():
    """An implementation that violates the output contract (returns a
    non-dict) after the fused program ran must fail the claimed tasks —
    never leave them RUNNING forever (waiters would hang)."""
    from repro.core.backends import base as bb

    engine, ac = fresh()
    jaxb = engine.backends["jax"]
    jaxb._impls[("badlib", "ok")] = bb.RoutineImpl(
        fn=lambda A: {"C": A + 1.0}, fusible=True)
    jaxb._impls[("badlib", "boom")] = bb.RoutineImpl(
        fn=lambda A: A * 2.0, fusible=True)      # contract violation

    class _L:
        ROUTINES = {"ok": lambda eng, A: {}, "boom": lambda eng, A: {}}

    engine.load_library("badlib", _L)
    try:
        al = ac.send_matrix(A)
        engine.scheduler.pause()
        f1 = ac.call_async("badlib", "ok", A=al)
        f2 = ac.call_async("badlib", "boom", A=f1["C"])
        engine.scheduler.resume()
        # the lead's own step delivered: eager semantics, it succeeds
        np.testing.assert_allclose(
            np.asarray(engine.get(f1.result()["C"],
                                  session=ac.session)),
            A + 1.0, rtol=1e-6)
        with pytest.raises(AlchemistError):      # and this returns, no hang
            f2.result()
    finally:
        ac.stop()
        engine.shutdown()


# ---------------------------------------------------------------------------
# cache: fused and unfused runs are indistinguishable to the cache
# ---------------------------------------------------------------------------
def test_cache_fingerprints_identical_fused_vs_unfused():
    engine_f, ac_f = fresh(cache_entries=64)
    engine_e, ac_e = fresh(cache_entries=64)
    try:
        xs = burst_chain(ac_f, ac_f.send_matrix(A), 3)
        assert engine_f.task_log.stats()["fused_tasks"] == 1

        x = ac_e.send_matrix(A)
        eager = [x]
        for _ in range(3):
            x = ac_e.wrap(ac_e.call("elemental", "multiply", A=x,
                                    B=eager[0])["C"])
            eager.append(x)
        assert engine_e.task_log.stats()["fused_tasks"] == 0

        for fused_m, eager_m in zip(xs, eager):
            fp_f = engine_f.fingerprint(fused_m.handle)
            fp_e = engine_e.fingerprint(eager_m.handle)
            assert fp_f == fp_e, (fp_f, fp_e)
            assert fp_f.startswith(("c:", "r:"))
    finally:
        ac_f.stop()
        engine_f.shutdown()
        ac_e.stop()
        engine_e.shutdown()


def test_warm_chain_is_served_from_cache_without_dispatch():
    engine, ac = fresh(cache_entries=64)
    try:
        burst_chain(ac, ac.send_matrix(A), 3)
        before = engine.task_log.stats()
        xs = burst_chain(ac, ac.send_matrix(A), 3)  # dedup + fast path
        after = engine.task_log.stats()
        assert after["dispatched"] == before["dispatched"]
        assert after["absorbed"] == before["absorbed"]
        np.testing.assert_allclose(xs[-1].to_numpy(), chain_power(A, 3),
                                   rtol=1e-3, atol=1e-5)
    finally:
        ac.stop()
        engine.shutdown()
