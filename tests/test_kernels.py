"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle,
across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gram.ops import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.rf_map.ops import rf_map_apply
from repro.kernels.rf_map.ref import rf_map_ref, rf_weights
from repro.kernels.swa.ops import swa_attention


@pytest.mark.parametrize("n,d", [(256, 128), (512, 256), (384, 200),
                                 (1000, 64), (128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_ref(n, d, dtype):
    a = jax.random.normal(jax.random.PRNGKey(n + d), (n, d), dtype)
    got = gram(a, use_pallas=True, bm=256, bn=128)
    want = gram_ref(a)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("n,d,dd", [(256, 128, 256), (300, 70, 200),
                                    (512, 440, 1024), (100, 33, 77)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rf_map_matches_ref(n, d, dd, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), dtype)
    w, b = rf_weights(d, dd, bandwidth=2.0, seed=1)
    got = rf_map_apply(x, w, b, use_pallas=True)
    want = rf_map_ref(x, w, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("s,window,bq,bk", [
    (128, 32, 64, 64), (256, 96, 64, 64), (256, 256, 128, 128),
    (512, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_matches_ref(s, window, bq, bk, dtype):
    key = jax.random.PRNGKey(s + window)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 4, s, 32), dtype)
    k = jax.random.normal(kk, (2, 2, s, 32), dtype)
    v = jax.random.normal(kv, (2, 2, s, 32), dtype)
    got = swa_attention(q, k, v, window=window, use_pallas=True, bq=bq, bk=bk)
    want = swa_attention(q, k, v, window=window, use_pallas=False)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_swa_equals_full_attention_when_window_covers_seq():
    """window >= S must reduce to plain causal attention."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, 128, 32), jnp.float32)
    got = swa_attention(q, q, q, window=128, use_pallas=True, bq=64, bk=64)
    # plain causal reference
    s = 128
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, q) * 32 ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -2e38)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, -1), q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
