"""Tests for extension round 2: LRU-scan kernel and chunked-vocab loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lru_scan.ops import lru_scan
from repro.kernels.lru_scan.ref import lru_scan_ref
from repro.train.loss import (
    chunked_unembed_cross_entropy,
    softmax_cross_entropy,
)


@pytest.mark.parametrize("b,s,w,bt,bw", [
    (2, 64, 128, 32, 64), (1, 100, 96, 128, 512), (3, 128, 512, 64, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_matches_ref(b, s, w, bt, bw, dtype):
    key = jax.random.PRNGKey(b + s + w)
    ka, kb, kh = jax.random.split(key, 3)
    # decays in (0, 1) like RG-LRU's a_t
    a = jax.nn.sigmoid(jax.random.normal(ka, (b, s, w))).astype(dtype)
    bb = (0.1 * jax.random.normal(kb, (b, s, w))).astype(dtype)
    h0 = jax.random.normal(kh, (b, w), jnp.float32)
    got = lru_scan(a, bb, h0, use_pallas=True, bt=bt, bw=bw)
    want = lru_scan_ref(a, bb, h0)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_lru_scan_carries_initial_state():
    a = jnp.ones((1, 4, 8)) * 0.5
    b = jnp.zeros((1, 4, 8))
    h0 = jnp.ones((1, 8)) * 16.0
    got = lru_scan(a, b, h0, use_pallas=True, bt=2, bw=8)
    np.testing.assert_allclose(np.asarray(got[0, :, 0]),
                               [8.0, 4.0, 2.0, 1.0], rtol=1e-6)


def test_chunked_xent_matches_reference_loss_and_grad():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 32, 16, 50
    x = jax.random.normal(key, (b, s, d), jnp.float32)
    emb = jax.random.normal(jax.random.PRNGKey(1), (v, d), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    labels = labels.at[0, -3:].set(-1)        # masked positions

    def ref(x, emb):
        logits = jnp.einsum("bsd,vd->bsv", x, emb)
        return softmax_cross_entropy(logits, labels)

    def chunked(x, emb):
        return chunked_unembed_cross_entropy(
            x, emb, labels, seq_chunk=8, compute_dtype=jnp.float32)

    np.testing.assert_allclose(float(ref(x, emb)), float(chunked(x, emb)),
                               rtol=1e-6)
    g0 = jax.grad(ref, argnums=(0, 1))(x, emb)
    g1 = jax.grad(chunked, argnums=(0, 1))(x, emb)
    for a, bb in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-5, atol=1e-6)


def test_model_loss_chunk_config_matches_unchunked():
    from repro.configs import get_reduced
    from repro.models import io as mio
    from repro.models.model import build_model
    from repro.nn.core import init_params
    from repro.common.config import ShapeConfig

    shape = ShapeConfig("t", seq_len=32, global_batch=2, mode="train")
    cfg = get_reduced("qwen3-4b")
    m0 = build_model(cfg)
    m1 = build_model(dataclasses.replace(cfg, loss_chunk=8))
    params = init_params(m0.param_specs(), jax.random.PRNGKey(0))
    batch = mio.make_batch(cfg, shape)
    l0, _ = m0.loss(params, batch)
    l1, _ = m1.loss(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-3)
