"""Property tests for the logical-sharding core (hypothesis): every spec
produced must divide the dims it shards, never reuse a mesh axis within a
tensor, and respect claim-order priority."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; pip install -e '.[test]' to run these")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
from jax.sharding import Mesh

from repro.common.sharding import DEFAULT_RULES, make_rules

AXIS_NAMES = [None, "batch", "seq", "cache_seq", "layers", "vocab", "embed",
              "mlp", "heads", "kv_heads", "experts", "state", "act_seq"]


def _mesh(shape=(1,), axes=("data",)):
    dev = np.array(jax.devices()[:1])
    # fake multi-axis mesh over one device is invalid; instead build the
    # rules against mesh metadata only via a size-1 mesh when needed.
    return Mesh(dev.reshape(shape), axes)


class _FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (rules only read metadata)."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self._shape = tuple(sizes.values())

    @property
    def devices(self):
        class _D:
            def __init__(self, shape):
                self.shape = shape
                self.size = int(np.prod(shape))

        return _D(self._shape)


@settings(max_examples=200, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=5),
    names=st.lists(st.sampled_from(AXIS_NAMES), min_size=1, max_size=5),
    data=st.integers(1, 16),
    model=st.integers(1, 16),
    pod=st.integers(1, 4),
)
def test_spec_always_divides_and_never_reuses_axes(dims, names, data,
                                                   model, pod):
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    mesh = _FakeMesh({"pod": pod, "data": data, "model": model})
    rules = make_rules(mesh)  # type: ignore[arg-type]
    spec = rules.spec_for(dims, names)
    sizes = {"pod": pod, "data": data, "model": model}
    seen = set()
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        total = 1
        for a in axes:
            assert a not in seen, "mesh axis used twice"
            seen.add(a)
            total *= sizes[a]
        assert dim % total == 0, (dim, axes, total)


@settings(max_examples=100, deadline=None)
@given(data=st.integers(2, 16), model=st.integers(2, 16))
def test_claim_order_gives_priority(data, model):
    """With claim_order, a later-listed dim must not steal an axis a
    higher-priority dim could use."""
    mesh = _FakeMesh({"data": data, "model": model})
    rules = make_rules(mesh)  # type: ignore[arg-type]
    # (layers, batch): both want 'data'; batch must win under its priority
    shape = (data * 4, data * 8)
    spec = rules.spec_for(shape, ("layers", "batch"), claim_order=[1, 0])
    assert tuple(spec)[1] is not None and "data" in (
        tuple(spec)[1] if isinstance(tuple(spec)[1], tuple)
        else (tuple(spec)[1],))
    assert tuple(spec)[0] in (None, "model")  # layers lost 'data'


def test_batch_claims_pod_and_data_when_divisible():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = make_rules(mesh)  # type: ignore[arg-type]
    spec = rules.spec_for((256, 4096), ("batch", "seq"))
    assert tuple(spec)[0] == ("pod", "data")
    spec1 = rules.spec_for((1, 4096), ("batch", "seq"))   # long_500k batch=1
    assert tuple(spec1)[0] is None


def test_partial_multiaxis_claim():
    """batch=8 on (pod=2, data=16): only pod divides — keep just pod."""
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = make_rules(mesh)  # type: ignore[arg-type]
    spec = rules.spec_for((8,), ("batch",))
    assert tuple(spec)[0] == "pod"
