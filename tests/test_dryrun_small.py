"""Dry-run machinery validated at CI scale: subprocesses get 8 fake host
devices (the 512-device production run is exercised by launch/dryrun.py
itself), covering the sharded lower+compile path, the expert-parallel
shard_map MoE, and the roofline HLO parsing."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src"),
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run(code: str):
    return subprocess.run([sys.executable, "-c", code], env=ENV,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.parametrize("arch,shape,mesh", [
    ("stablelm-1.6b", "train_4k", "2,4"),
    ("deepseek-v2-lite-16b", "decode_32k", "2,4"),
    ("rwkv6-1.6b", "long_500k", "2,4"),
    # 3-axis mesh exercises the multi-pod ('pod') axis at CI scale
    ("qwen3-4b", "train_4k", "2,2,2"),
])
def test_dryrun_lowers_on_test_mesh(arch, shape, mesh, tmp_path):
    out = os.path.join(tmp_path, "dr")
    code = f"""
import sys
sys.argv = ["dryrun", "--arch", "{arch}", "--shape", "{shape}",
            "--test-mesh", "{mesh}", "--out", "{out}"]
import runpy
runpy.run_module("repro.launch.dryrun", run_name="__main__")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    files = os.listdir(out)
    assert len(files) == 1
    data = json.load(open(os.path.join(out, files[0])))
    assert data["chips"] == 8
    assert data["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert data["roofline"]["model_flops"] > 0


def test_moe_expert_parallel_matches_reference():
    """shard_map EP path on 8 devices == single-device reference path."""
    code = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.common.config import ModelConfig, MoEConfig
from repro.common.sharding import make_rules, use_rules
from repro.nn.core import init_params
from repro.nn.moe import moe_spec, moe_apply

# capacity_factor high enough that no tokens drop: the EP and reference
# paths then agree exactly (drop patterns legitimately differ per DP shard)
cfg = ModelConfig(name="t", num_layers=1, d_model=64, num_heads=4,
                  num_kv_heads=4, d_ff=128, vocab_size=100,
                  moe=MoEConfig(num_experts=8, num_shared_experts=1,
                                top_k=2, expert_ff=32, capacity_factor=8.0))
params = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)

y_ref, aux_ref = moe_apply(params, x, cfg, compute_dtype=jnp.float32)

devs = np.array(jax.devices()).reshape(2, 4)
mesh = Mesh(devs, ("data", "model"))
rules = make_rules(mesh)
with use_rules(rules):
    y_ep, aux_ep = jax.jit(
        lambda p, x: moe_apply(p, x, cfg, compute_dtype=jnp.float32)
    )(params, x)

np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-4)
# per-shard aux estimator differs from the global one by routing covariance
np.testing.assert_allclose(float(aux_ref), float(aux_ep), rtol=0.1)
print("EP-OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP-OK" in r.stdout


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes_by_kind

    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128] %x), replica_groups={}
  %ag.1 = bf16[4,256]{1,0} all-gather(bf16[4,64] %y), dimensions={1}
  %p = f32[8]{0} add(f32[8] %a, f32[8] %b)
  %cp-start = (f32[2,2], f32[2,2]) collective-permute-start(f32[2,2] %z)
  %cp-done = f32[2,2] collective-permute-done(%cp-start)
"""
    got = collective_bytes_by_kind(hlo)
    assert got["all-reduce"] == 16 * 128 * 4
    assert got["all-gather"] == 4 * 256 * 2
    # tuple results count the moved buffer once (first element)
    assert got["collective-permute"] == 2 * 2 * 4
    assert "add" not in got


def test_model_flops_sanity():
    """Analytic FLOPs ~ 6ND for a dense model at short context."""
    from repro.common.config import SHAPES
    from repro.configs import get_config
    from repro.launch.roofline import model_flops, param_count

    cfg = get_config("qwen3-4b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    n = param_count(cfg) - cfg.vocab_size * cfg.d_model  # non-embedding
    d = shape.global_batch * shape.seq_len
    ratio = mf / (6 * n * d)
    assert 0.8 < ratio < 1.8, ratio
