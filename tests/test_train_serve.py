"""Trainer/server substrate tests: optimizer numerics, checkpoint roundtrip,
GaLore offload refresh, data pipeline determinism, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig, ShapeConfig, TrainConfig
from repro.configs import get_reduced
from repro.core import AlchemistContext
from repro.core.libraries import elemental
from repro.data.pipeline import SyntheticLM
from repro.models import io as mio
from repro.models.model import build_model
from repro.nn.core import init_params
from repro.serve.engine import Request, ServingEngine
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.loop import make_train_step, train
from repro.train.optim import (
    GaLoreState,
    adamw_init,
    adamw_update,
    project_grads,
    refresh_projectors,
)

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, mode="train")


def test_adamw_first_step_matches_reference():
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=1, weight_decay=0.0,
                     grad_clip=1e9)
    params = {"w": jnp.ones((3,)) * 2.0}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    state = adamw_init(params)
    new_params, state, _ = adamw_update(grads, state, params, tc)
    # bias-corrected first step = -lr * sign-ish update
    g = np.asarray([0.1, -0.2, 0.3])
    want = 2.0 - 1e-2 * g / (np.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-4)


def test_train_reduces_loss_on_synthetic_bigrams():
    cfg = get_reduced("stablelm-1.6b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, SHAPE, seed=0, bigram_q=0.9)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=30)
    params, history = train(model, params,
                            (data.batch(s) for s in range(30)), tc,
                            log_every=29)
    assert history[-1]["loss"] < history[0]["loss"] - 0.3, history


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("qwen3-4b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(1))
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, opt, step=7)
    p2, o2, step = restore_checkpoint(path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2["step"]) == 0


def test_galore_offloaded_projection_reduces_rank():
    ac = AlchemistContext(num_workers=1)
    ac.register_library("elemental", elemental)
    rng = np.random.RandomState(0)
    low = rng.randn(64, 4) @ rng.randn(4, 32)          # rank-4 gradient
    grads = {"w": jnp.asarray(low + 0.001 * rng.randn(64, 32), jnp.float32)}
    gal = refresh_projectors(ac, grads, rank=4)
    assert "w" in gal.projectors
    pg = project_grads(grads, gal)["w"]
    # projection preserves the low-rank signal
    rel = float(jnp.linalg.norm(pg - grads["w"]) / jnp.linalg.norm(grads["w"]))
    assert rel < 0.05
    # and the result is (numerically) rank <= 4
    s = np.linalg.svd(np.asarray(pg), compute_uv=False)
    assert s[4] < 1e-3 * s[0]


def test_data_pipeline_is_deterministic_and_learnable():
    cfg = get_reduced("stablelm-1.6b")
    d1 = SyntheticLM(cfg, SHAPE, seed=5).batch(3)
    d2 = SyntheticLM(cfg, SHAPE, seed=5).batch(3)
    np.testing.assert_array_equal(d1["tokens"], d2["tokens"])
    # bigram structure: labels follow perm[tokens] more often than chance
    data = SyntheticLM(cfg, SHAPE, seed=5, bigram_q=0.5)
    b = data.batch(0)
    hit = np.mean(b["labels"] == data.perm[b["tokens"]])
    assert hit > 0.3


def test_serving_engine_waves_and_determinism():
    cfg = get_reduced("qwen3-4b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(2))
    eng = ServingEngine(model, params, max_batch=2)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    for p in prompts:
        eng.submit(Request(prompt=p, max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.stats["prefills"] == 2                    # two waves
    # greedy decode is deterministic
    eng2 = ServingEngine(model, params, max_batch=2)
    for p in prompts:
        eng2.submit(Request(prompt=p, max_new_tokens=4))
    done2 = eng2.run()
    for a, b in zip(done, done2):
        assert a.out_tokens == b.out_tokens
