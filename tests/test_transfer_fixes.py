"""Transfer-layer correctness sweep: dtype-aware chunk sizing (float32
matrices were getting 2x-oversized chunks and 2x-inflated modeled costs),
bounded-memory to_client streaming (no whole-matrix staging buffer), and
aggregate stream records agreeing with the sum of their per-chunk records
even when shard-boundary cuts leave runt chunks."""
import numpy as np
import pytest

from repro.core import AlchemistContext, AlchemistEngine, transfer
from repro.core.engine import make_engine_mesh
from repro.frontend.rowmatrix import RowMatrix

RNG = np.random.RandomState(3)


@pytest.fixture()
def engine():
    return AlchemistEngine(make_engine_mesh(1))


# =====================================================================
# dtype tracking (the float32 regression)
# =====================================================================
def test_rowmatrix_tracks_dtype_and_nbytes():
    x32 = RNG.randn(50, 10).astype(np.float32)
    rm = RowMatrix.from_array(x32, 4)
    assert rm.dtype == np.float32
    assert rm.nbytes == 50 * 10 * 4                 # not * 8
    rm64 = RowMatrix.from_array(x32.astype(np.float64), 4)
    assert rm64.nbytes == 50 * 10 * 8
    assert RowMatrix.random(20, 5).dtype == np.float64


def test_map_rows_derives_dtype_lazily():
    rm = RowMatrix.from_array(RNG.randn(40, 8), 4)
    mapped = rm.map_rows(lambda p: p.astype(np.float32))
    assert mapped._dtype is None                    # not eagerly computed
    assert mapped.dtype == np.float32
    assert mapped.nbytes == 40 * 8 * 4


def test_float32_rowmatrix_chunks_sized_by_real_itemsize(engine):
    """1024x1024 f32 is exactly DEFAULT_CHUNK_BYTES: with the real 4-byte
    itemsize it crosses as ONE chunk; the old hardcoded itemsize=8 halved
    chunk_rows and produced two."""
    x = RNG.randn(1024, 1024).astype(np.float32)
    rm = RowMatrix.from_array(x, 4)
    handle, rec = transfer.to_engine(engine, rm)
    assert rec.num_chunks == 1
    assert rec.nbytes == x.nbytes == 1024 * 1024 * 4
    chunk_recs = [r for r in engine.transfer_log.records
                  if r.chunk_index >= 0]
    assert sum(r.nbytes for r in chunk_recs) == x.nbytes
    np.testing.assert_array_equal(np.asarray(engine.get(handle)), x)


def test_float32_roundtrip_preserves_dtype_and_values(engine):
    ac = AlchemistContext(engine=engine)
    x = RNG.randn(100, 16).astype(np.float32)
    al = ac.send_matrix(x, chunk_rows=13)
    back = al.to_row_matrix(num_partitions=5)
    assert back.dtype == np.float32
    np.testing.assert_array_equal(back.collect(), x)


def test_chunk_rows_for_uses_itemsize():
    assert transfer.chunk_rows_for((1000, 1024), 4) == \
        2 * transfer.chunk_rows_for((1000, 1024), 8)


# =====================================================================
# to_client streaming (bounded peak host memory)
# =====================================================================
def test_to_client_never_allocates_a_full_matrix_buffer(engine,
                                                        monkeypatch):
    """Chunks land directly in per-partition blocks: the largest single
    host allocation is one partition, and the total allocated equals the
    matrix itself — no extra whole-matrix staging buffer."""
    x = RNG.randn(200, 32).astype(np.float32)
    ac = AlchemistContext(engine=engine)
    al = ac.send_matrix(x)

    allocs = []
    real_empty = np.empty

    def recording_empty(shape, *a, **kw):
        out = real_empty(shape, *a, **kw)
        allocs.append(out.nbytes)
        return out

    monkeypatch.setattr(transfer.np, "empty", recording_empty)
    rm = ac.fetch(al.handle, num_partitions=8, chunk_rows=17)
    monkeypatch.undo()

    assert allocs, "to_client should allocate its partition blocks"
    max_partition_bytes = -(-200 // 8) * 32 * 4
    assert max(allocs) <= max_partition_bytes     # never the full matrix
    assert sum(allocs) == x.nbytes                # exactly the result
    np.testing.assert_array_equal(rm.collect(), x)


def test_to_client_partitioning_matches_array_split(engine):
    """Partition sizes must stay what from_array produced (np.array_split
    semantics) so downstream per-partition consumers see no change."""
    ac = AlchemistContext(engine=engine)
    x = RNG.randn(100, 8)
    al = ac.send_matrix(x)
    rm = ac.fetch(al.handle, num_partitions=8)
    want_sizes = [b.shape[0] for b in np.array_split(x, 8, axis=0)]
    got_sizes = [np.asarray(rm.rdd.partition(i)).shape[0]
                 for i in range(rm.rdd.num_partitions)]
    assert got_sizes == want_sizes
    assert rm.row_offsets == [0] + list(np.cumsum(want_sizes))


def test_to_client_one_dim_handle(engine):
    """Singular-value vectors (1-D handles) still round-trip."""
    ac = AlchemistContext(engine=engine)
    import jax.numpy as jnp
    h = engine.put(jnp.arange(37, dtype=jnp.float32))
    got = ac.wrap(h).to_numpy()
    np.testing.assert_array_equal(got, np.arange(37, dtype=np.float32))


# =====================================================================
# aggregate record == sum of per-chunk records (runt chunks)
# =====================================================================
@pytest.mark.parametrize("direction", ["to_engine", "to_client"])
def test_aggregate_matches_per_chunk_sum_with_runts(engine, direction):
    """100 rows at chunk_rows=33 leaves a 1-row runt: the aggregate's
    stream model must be built from the actual chunk list, not a mean
    chunk size, so it equals the per-chunk records' sum exactly."""
    x = RNG.randn(100, 8)
    if direction == "to_engine":
        _, agg = transfer.to_engine(engine, x, chunk_rows=33)
    else:
        handle, _ = transfer.to_engine(engine, x, chunk_rows=10**9)
        engine.transfer_log.records.clear()
        _, agg = transfer.to_client(engine, handle, num_partitions=1,
                                    chunk_rows=33)
    chunk_recs = [r for r in engine.transfer_log.records
                  if r.chunk_index >= 0 and r.direction == direction]
    # client side streams the f64 source; the engine array is f32 (x64
    # off), so the fetch direction moves half the bytes per row
    row_bytes = 8 * 8 if direction == "to_engine" else 8 * 4
    assert [r.nbytes for r in chunk_recs] == \
        [33 * row_bytes] * 3 + [1 * row_bytes]
    assert agg.num_chunks == len(chunk_recs) == 4
    assert agg.nbytes == sum(r.nbytes for r in chunk_recs)
    np.testing.assert_allclose(
        agg.modeled_socket_s,
        sum(r.modeled_socket_s for r in chunk_recs), rtol=1e-12)


def test_uniform_chunks_agree_with_uniform_stream_model(engine):
    """When chunks ARE uniform, the chunk-list model reduces to the
    uniform-chunk stream model the Table-3 sweep uses."""
    from repro.core.costmodel import (
        stream_transfer_seconds, stream_transfer_seconds_from_chunks)
    sizes = [1 << 20] * 8
    np.testing.assert_allclose(
        stream_transfer_seconds_from_chunks(sizes, 20, 20),
        stream_transfer_seconds(8 << 20, 1 << 20, 20, 20), rtol=1e-12)
