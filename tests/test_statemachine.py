"""The lifecycle state-machine spec + runtime monitor, unit level.

Three halves:

* the declarative spec (``statemachine.MACHINES``) is internally
  consistent and the docs tables render from it verbatim;
* the runtime monitor (``StmTrace``) yields the right verdict for every
  violation class — illegal edge, remint, orphan, dead-scope activity,
  terminal-scope obligation — on crafted transition streams, and stays
  silent on legal ones;
* the env-gated plumbing (``tracer()``/``enabled()``) is zero-cost off.

The monitor's *integration* (instrumented engine/scheduler/server under
real load) is exercised by the autouse ``stm_monitor`` fixture over
``test_server_faults.py`` and ``test_qos.py``, and driven through
adversarial interleavings by ``test_explore.py``.
"""
import os

import pytest

from repro.analysis import statemachine
from repro.analysis.statemachine import (
    Edge, Machine, MACHINES, MACHINES_BY_NAME, Obligation, ScopeCheck,
    StmTrace, render_tables, validate_machines)


# =====================================================================
# the spec itself
# =====================================================================
def test_real_machines_are_internally_consistent():
    assert validate_machines() == []


def test_every_machine_terminal_is_reachable():
    for m in MACHINES:
        dsts = {e.dst for e in m.edges}
        for t in m.terminal:
            assert t in dsts or t == m.initial, \
                f"{m.name}: terminal {t} unreachable via declared edges"


def test_validate_catches_crafted_inconsistencies():
    bad = Machine(
        name="bad", subject="x", modules=("m.py",),
        guarded=("_g",), states=("A", "B"),
        initial="ZZZ",                          # not a state
        terminal=("B", "GONE"),                 # GONE not a state
        lock=None, lockattr=None,
        mint_sites=("mk",),
        edges=(Edge("A", "NOPE", "step"),),     # NOPE not a state
        obligations=(Obligation("ghost", ("x",), "r"),),  # undeclared site
        caller_locked=("phantom",),             # undeclared site
        scope_checks=(ScopeCheck("unknown", ("A",), "r"),),
    )
    problems = validate_machines((bad,))
    text = "\n".join(problems)
    assert "initial 'ZZZ'" in text
    assert "terminal 'GONE'" in text
    assert "unknown state 'NOPE'" in text
    assert "obligation on undeclared site 'ghost'" in text
    assert "caller_locked names undeclared site 'phantom'" in text
    assert "unknown machine 'unknown'" in text


def test_session_scope_checks_cover_the_interacting_machines():
    """The cross-machine teardown contract is declared, not implied:
    a forgotten session must have drained tasks, aborted uploads, and
    released reservations."""
    sc = {c.machine: c for c in MACHINES_BY_NAME["session"].scope_checks}
    assert set(sc) == {"task", "upload", "reservation"}
    assert set(sc["task"].bad_states) == {"QUEUED", "RUNNING"}
    assert sc["upload"].bad_states == ("OPEN",)
    assert sc["reservation"].bad_states == ("ACTIVE",)
    for c in sc.values():                   # bulk shutdown is exempt
        assert "shutdown" in c.exempt_sites


def test_docs_tables_match_the_spec():
    """docs/architecture.md embeds render_tables() between markers; the
    two must be byte-identical or the docs have drifted from the code."""
    doc = os.path.join(os.path.dirname(__file__), "..", "docs",
                       "architecture.md")
    with open(doc) as f:
        text = f.read()
    begin, end = "<!-- STM_TABLES_BEGIN -->\n", "<!-- STM_TABLES_END -->"
    assert begin in text and end in text, "STM table markers missing"
    embedded = text.split(begin, 1)[1].split(end, 1)[0]
    assert embedded == render_tables(), (
        "docs/architecture.md state-machine tables differ from "
        "statemachine.render_tables() — re-render the block between the "
        "STM_TABLES markers")


# =====================================================================
# the runtime monitor, verdict by verdict
# =====================================================================
D = "dom"           # a fake engine domain


def test_legal_task_lifecycle_is_clean():
    tr = StmTrace()
    tr.mint("task", (D, 1), site="submit", scope=(D, 7))
    tr.note("task", (D, 1), "RUNNING", site="_worker")
    tr.note("task", (D, 1), "DONE", site="_finish")
    tr.note("task", (D, 1), "RELEASED", site="release")
    tr.assert_clean()
    assert tr.state_of("task", (D, 1)) == "RELEASED"
    assert tr.report()["transitions"] == 4
    assert tr.report()["live"] == {}        # terminal rows are not live


def test_illegal_edge_is_recorded_not_raised():
    tr = StmTrace()
    tr.mint("task", (D, 1), site="submit")
    tr.note("task", (D, 1), "RELEASED", site="release")  # QUEUED->RELEASED
    [v] = tr.violations()
    assert v["kind"] == "illegal-edge" and v["machine"] == "task"
    assert "QUEUED -> RELEASED" in v["detail"]
    with pytest.raises(AssertionError, match="illegal-edge"):
        tr.assert_clean()


def test_remint_of_a_live_subject():
    tr = StmTrace()
    tr.mint("task", (D, 1), site="submit")
    tr.mint("task", (D, 1), site="submit")          # still QUEUED
    assert [v["kind"] for v in tr.violations()] == ["remint"]


def test_remint_after_terminal_is_legal():
    """Key reuse after RELEASED is a fresh subject, not a violation
    (task ids are monotonic in practice, but the monitor must not
    depend on that)."""
    tr = StmTrace()
    tr.mint("task", (D, 1), site="submit")
    tr.note("task", (D, 1), "FAILED", site="_finish")
    tr.note("task", (D, 1), "RELEASED", site="release")
    tr.mint("task", (D, 1), site="submit")
    tr.assert_clean()


def test_orphan_transition():
    tr = StmTrace()
    tr.note("task", (D, 99), "RUNNING", site="_worker")
    [v] = tr.violations()
    assert v["kind"] == "orphan" and "never minted" in v["detail"]


def test_terminal_scope_obligation_fires_on_undrained_session():
    """Session reaches FORGOTTEN while a task scoped to it is still
    RUNNING — exactly the teardown contract disconnect must uphold."""
    tr = StmTrace()
    tr.mint("session", (D, 5), site="connect")
    tr.mint("task", (D, 1), site="submit", scope=(D, 5))
    tr.note("task", (D, 1), "RUNNING", site="_worker")
    tr.note("session", (D, 5), "DRAINING", site="disconnect")
    tr.note("session", (D, 5), "FORGOTTEN", site="disconnect")
    kinds = [v["kind"] for v in tr.violations()]
    assert kinds == ["obligation"]
    assert "still RUNNING" in tr.violations()[0]["detail"]


def test_terminal_scope_obligation_exempt_for_bulk_shutdown():
    tr = StmTrace()
    tr.mint("session", (D, 5), site="connect")
    tr.mint("task", (D, 1), site="submit", scope=(D, 5))
    tr.note("task", (D, 1), "RUNNING", site="_worker")
    tr.note("session", (D, 5), "FORGOTTEN", site="shutdown")
    assert tr.violations() == []            # shutdown is exempt


def test_dead_scope_mint_and_activity():
    """Nothing may be minted into, or move non-terminally inside, a
    forgotten session — the invariant the submit-vs-disconnect fix
    protects."""
    tr = StmTrace()
    tr.mint("session", (D, 5), site="connect")
    tr.mint("task", (D, 1), site="submit", scope=(D, 5))
    tr.note("task", (D, 1), "FAILED", site="_finish")   # QUEUED->FAILED ok
    tr.note("session", (D, 5), "FORGOTTEN", site="shutdown")
    tr.mint("task", (D, 2), site="submit", scope=(D, 5))
    tr.note("task", (D, 2), "RUNNING", site="_worker")
    tr.note("task", (D, 2), "DONE", site="_finish")
    tr.note("task", (D, 2), "RELEASED", site="release")  # terminal: allowed
    kinds = [v["kind"] for v in tr.violations()]
    assert kinds == ["dead-scope", "dead-scope", "dead-scope"]


def test_reset_clears_everything():
    tr = StmTrace()
    tr.mint("task", (D, 1), site="submit")
    tr.note("task", (D, 1), "RELEASED", site="release")  # violation
    assert tr.violations()
    tr.reset()
    assert tr.violations() == [] and tr.report()["transitions"] == 0
    assert tr.state_of("task", (D, 1)) is None


def test_report_counts_live_subjects_per_machine():
    tr = StmTrace()
    tr.mint("session", (D, 1), site="connect")
    tr.mint("task", (D, 1), site="submit")
    tr.mint("task", (D, 2), site="submit")
    rep = tr.report()
    assert rep["live"] == {"session": 1, "task": 2}
    assert rep["violations"] == []


# =====================================================================
# the env gate
# =====================================================================
def test_tracer_is_null_when_disabled(monkeypatch):
    monkeypatch.delenv(statemachine.ENV_FLAG, raising=False)
    assert not statemachine.enabled()
    t = statemachine.tracer()
    assert t.enabled is False
    t.mint("task", (D, 1), site="submit")   # all no-ops
    t.note("task", (D, 1), "RUNNING", site="_worker")
    assert statemachine.TRACE.state_of("task", (D, 1)) is None


def test_tracer_is_live_monitor_when_enabled(monkeypatch):
    monkeypatch.setenv(statemachine.ENV_FLAG, "1")
    assert statemachine.tracer() is statemachine.TRACE
    assert statemachine.TRACE.enabled is True
    monkeypatch.setenv(statemachine.ENV_FLAG, "0")
    assert not statemachine.enabled()       # "0" counts as off


def test_engine_binds_monitor_at_construction(monkeypatch):
    """An engine built with the flag set actually records transitions:
    connect/disconnect walks the session machine end to end."""
    monkeypatch.setenv(statemachine.ENV_FLAG, "1")
    statemachine.TRACE.reset()
    from repro.core.engine import AlchemistEngine
    eng = AlchemistEngine(scheduler_workers=1, cache_entries=0)
    try:
        sess = eng.connect("probe")
        dom = eng._stm_dom
        assert statemachine.TRACE.state_of(
            "session", (dom, sess.id)) == "ACTIVE"
        eng.disconnect(sess.id)
        assert statemachine.TRACE.state_of(
            "session", (dom, sess.id)) == "FORGOTTEN"
        eng.disconnect(sess.id)             # idempotent: no re-notes
    finally:
        eng.shutdown()
    statemachine.TRACE.assert_clean()
    statemachine.TRACE.reset()
