"""Targeted correctness tests: attention masks (prefix/sliding), RG-LRU
parallel-scan equivalence, serving with modality extras."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.nn.attention import _mask
from repro.nn import core as nncore
from repro.nn.rglru import apply_rglru, rglru_spec


def test_prefix_mask_is_bidirectional_in_prefix():
    b, s = 1, 8
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    m = _mask(pos, pos, causal=True, prefix_len=4)[0, 0, 0]
    m = np.asarray(m)
    # prefix block: fully connected
    assert m[:4, :4].all()
    # text attends prefix + causal text
    assert m[6, :7].all() and not m[6, 7]
    # prefix does NOT attend text
    assert not m[2, 5]


def test_sliding_window_mask():
    b, s, w = 1, 10, 3
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    m = np.asarray(_mask(pos, pos, causal=True, window=w)[0, 0, 0])
    for q in range(s):
        for k in range(s):
            expect = (k <= q) and (k > q - w)
            assert m[q, k] == expect, (q, k)


def test_invalid_kv_positions_masked():
    pos = jnp.asarray([[5]], jnp.int32)
    kv = jnp.asarray([[0, 1, -1, 3]], jnp.int32)     # slot 2 never written
    m = np.asarray(_mask(pos, kv, causal=True)[0, 0, 0, 0])
    assert list(m) == [True, True, False, True]


def test_rglru_associative_scan_matches_sequential():
    """The parallel prefix recurrence must equal step-by-step decode."""
    cfg = ModelConfig(name="t", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=10, lru_width=32)
    params = nncore.init_params(rglru_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    full, _ = apply_rglru(params, x, cfg, compute_dtype=jnp.float32)

    from repro.nn.rglru import RGLRUCache

    cache = RGLRUCache(h=jnp.zeros((2, 32)), conv=jnp.zeros((2, 3, 32)))
    outs = []
    for t in range(12):
        y, cache = apply_rglru(params, x[:, t : t + 1], cfg, cache=cache,
                               compute_dtype=jnp.float32)
        outs.append(y[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_serving_with_modality_extras():
    """VLM and audio archs serve through the engine with stub frontends."""
    from repro.configs import get_reduced
    from repro.models.model import build_model
    from repro.serve.engine import Request, ServingEngine

    for arch, extra_key in (("paligemma-3b", "patch_embeds"),
                            ("whisper-medium", "frames")):
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = nncore.init_params(model.param_specs(),
                                    jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_batch=2)
        rng = np.random.RandomState(0)
        if extra_key == "patch_embeds":
            def extras(n):
                return {"patch_embeds": 0.02 * rng.randn(
                    n, cfg.prefix_len, cfg.d_model).astype(np.float32)}
        else:
            def extras(n):
                return {"frames": 0.02 * rng.randn(
                    n, cfg.encoder_seq, cfg.encoder_d_model)
                    .astype(np.float32)}
        for _ in range(2):
            eng.submit(Request(
                prompt=rng.randint(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=3))
        done = eng.run(extras_fn=extras)
        assert all(len(r.out_tokens) == 3 for r in done), arch
