"""End-to-end behaviour tests: the paper's workflow (Fig. 2) run whole —
client data -> offload -> chained engine calls -> results back in the
client's row-partitioned world — plus the trainer using the offload service.
"""
import numpy as np

import jax

from repro.common.config import ShapeConfig, TrainConfig
from repro.configs import get_reduced
from repro.core import AlchemistContext
from repro.core.libraries import elemental, skylark
from repro.data.pipeline import SyntheticLM
from repro.frontend.rowmatrix import RowMatrix
from repro.models.model import build_model
from repro.nn.core import init_params
from repro.train.loop import make_train_step
from repro.train.optim import adamw_init, refresh_projectors


def test_paper_fig2_workflow():
    """The exact shape of the paper's usage example, end to end."""
    ac = AlchemistContext(num_workers=1)
    ac.register_library("elemental", elemental)

    a = RowMatrix.random(120, 24, num_partitions=6, seed=0)
    al_a = ac.send_matrix(a)                       # AlMatrix(A)
    res = ac.call("elemental", "qr", A=al_a)       # QRDecomposition(alA)
    q = ac.wrap(res["Q"]).to_row_matrix()          # alQ.toIndexedRowMatrix()
    r = ac.wrap(res["R"]).to_row_matrix()
    recon = q.collect() @ r.collect()
    np.testing.assert_allclose(recon, a.collect(), atol=1e-4)
    ac.stop()


def test_speech_pipeline_small_scale():
    """§4.1 at CPU scale: raw features cross, expansion + CG engine-side."""
    ac = AlchemistContext(num_workers=1)
    ac.register_library("skylark", skylark)
    rng = np.random.RandomState(0)
    n, d, c, rf = 400, 24, 6, 128
    x = rng.randn(n, d)
    al_x = ac.send_matrix(x)
    al_y = ac.send_matrix(rng.randn(n, c))
    res = ac.call("skylark", "cg_solve", X=al_x, Y=al_y, lam=1e-4,
                  rf_dim=rf, max_iters=600, tol=1e-8)
    assert res["relative_residual"] < 1e-6
    assert res["iterations"] > 0


def test_trainer_uses_offloaded_svd_service():
    """GaLore-style projector refresh through the Alchemist engine inside a
    real (tiny) training run."""
    cfg = get_reduced("qwen3-4b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    shape = ShapeConfig("s", seq_len=32, global_batch=2, mode="train")
    data = SyntheticLM(cfg, shape, seed=1, bigram_q=0.9)

    ac = AlchemistContext(num_workers=1)
    ac.register_library("elemental", elemental)

    grads = jax.grad(lambda p: model.loss(p, data.batch(0))[0])(params)
    gal = refresh_projectors(ac, grads, rank=8)
    assert len(gal.projectors) > 0

    tc = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=12)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, tc, galore_state=gal))
    losses = []
    for s in range(8):
        params, opt, metrics = step(params, opt, data.batch(s))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
